"""Prompt and Generation Task Ordering (§3.4).

Three factors, in order:
  1. JCT-SLO deadline  — ascending, bucketed into magnitude ranges;
  2. occupied KVC      — descending, bucketed (release KVC earlier, O5);
  3. predicted RL (GTs) / prompt length (PTs) — descending (fast near-exact
     fits when filling KVC / TFS via binary search).

Two ways to consume the ordering:
  * ``sort_queue``   — full re-sort (reference semantics, O(n log n) per
    iteration with a Python key function on every element);
  * ``OrderedQueue`` — a drop-in queue replacement (append / remove / len /
    iteration) that maintains the same ordering incrementally: keys are
    computed once on append, removal is O(1) via an rid index map, and
    only requests whose deadline bucket has actually rolled over are
    re-keyed (a time-ordered heap makes that O(log n) amortized).
    ``sorted_view(now)`` is guaranteed to return exactly what
    ``sort_queue(queue, now)`` would, including stable tie-breaking.

The priority index behind ``OrderedQueue`` is pluggable
(``index="skiplist"`` default, ``"list"`` legacy): the skip list makes
insert and remove O(log n), where the bisected list paid an O(n) memmove
per insort/removal (the last O(n) term in queue maintenance). Element
order is fully determined by (key, seq) either way — the skip list's
tower heights only affect constants — so batch decisions are bitwise
identical across indexes (tests/test_scheduler_determinism.py).
"""
from __future__ import annotations

import bisect
import heapq
import random
from typing import Dict, List, Optional, Sequence, Tuple

from .request import Request

DEADLINE_EDGES = (0.2, 0.5, 2.0)          # s, paper's example ranges
KVC_BUCKET = 128                          # tokens per occupied-KVC range
LEN_BUCKET = 128                          # tokens per RL/prompt-length range


def deadline_bucket(req: Request, now: float) -> int:
    slack = req.slo_deadline - now
    return bisect.bisect_left(DEADLINE_EDGES, slack)


def order_key(req: Request, now: float, is_gt: bool) -> Tuple[int, int, int]:
    length = req.remaining_predicted if is_gt else req.prompt_len
    return (deadline_bucket(req, now),
            -(req.occupied_kvc // KVC_BUCKET),
            -length)


def sort_queue(queue: List[Request], now: float, is_gt: bool) -> List[Request]:
    return sorted(queue, key=lambda r: order_key(r, now, is_gt))


def _next_bucket_change(req: Request, bucket: int) -> float:
    """Time at which the request's deadline bucket next decrements: the
    moment its slack drops to the edge below its current bucket."""
    if bucket <= 0:
        return float("inf")
    return req.slo_deadline - DEADLINE_EDGES[bucket - 1]


class _ListIndex:
    """Legacy priority index: a flat sorted list + bisect. Insert and
    remove pay an O(n) memmove; bulk insert merges two sorted runs."""

    def __init__(self):
        self._entries: List[list] = []    # sorted [key, seq, req]

    def insert(self, key, seq: int, req: Request) -> None:
        bisect.insort(self._entries, [key, seq, req])

    def remove(self, key, seq: int) -> None:
        # the stored key always matches the stored entry (written together
        # by the queue), so the bisect is exact
        i = bisect.bisect_left(self._entries, [key, seq])
        assert self._entries[i][1] == seq, (key, seq)
        del self._entries[i]

    def bulk_insert(self, entries: List[list]) -> None:
        """Merge a large batch with one sort + merge instead of per-
        element insort (Timsort gallops over the two sorted runs)."""
        entries.sort(key=lambda e: (e[0], e[1]))
        self._entries = list(heapq.merge(self._entries, entries,
                                         key=lambda e: (e[0], e[1])))

    @staticmethod
    def use_bulk(pending: int, indexed: int) -> bool:
        """Every per-item insort pays an O(n) memmove, so merging is the
        win for any non-trivial batch."""
        return pending > 64

    def reqs(self) -> List[Request]:
        return [e[2] for e in self._entries]


class _SkipListIndex:
    """Skip-list priority index: O(log n) insert/remove with no memmove.

    Nodes are ``[ckey, req, forwards]`` with ``ckey = (key, seq)``; the
    head is a sentinel. Tower heights come from a deterministic seeded
    generator, so a given operation sequence always builds the same
    structure — and element *order* is independent of heights anyway,
    which is what bitwise-identical scheduling decisions require.
    """

    MAX_LEVEL = 32

    def __init__(self):
        self._head = [None, None, [None]]
        self._level = 1                     # live levels in the head tower
        self._rng = random.Random(0x5EED)

    def _height(self) -> int:
        h = 1
        bits = self._rng.getrandbits(self.MAX_LEVEL)
        while bits & 1 and h < self.MAX_LEVEL:
            h += 1
            bits >>= 1
        return h

    def insert(self, key, seq: int, req: Request) -> None:
        ckey = (key, seq)
        update = [self._head] * max(self._level, 1)
        cur = self._head
        for lvl in range(self._level - 1, -1, -1):
            nxt = cur[2][lvl]
            while nxt is not None and nxt[0] < ckey:
                cur = nxt
                nxt = cur[2][lvl]
            update[lvl] = cur
        h = self._height()
        node = [ckey, req, [None] * h]
        if h > self._level:
            self._head[2].extend([None] * (h - self._level))
            update.extend([self._head] * (h - self._level))
            self._level = h
        for lvl in range(h):
            prev = update[lvl]
            node[2][lvl] = prev[2][lvl]
            prev[2][lvl] = node

    def remove(self, key, seq: int) -> None:
        ckey = (key, seq)
        cur = self._head
        found = None
        for lvl in range(self._level - 1, -1, -1):
            nxt = cur[2][lvl]
            while nxt is not None and nxt[0] < ckey:
                cur = nxt
                nxt = cur[2][lvl]
            if nxt is not None and nxt[0] == ckey:
                cur[2][lvl] = nxt[2][lvl]
                found = nxt
        assert found is not None, (key, seq)
        while self._level > 1 and self._head[2][self._level - 1] is None:
            self._head[2].pop()
            self._level -= 1

    def bulk_insert(self, entries: List[list]) -> None:
        """Merge a large sorted batch in O(n): walk the current level-0
        chain, merge with the new entries, and rebuild perfectly balanced
        towers (node i gets height 1 + trailing_zeros(i)) — deterministic
        and far cheaper than n Python-level tower searches (the arrival
        burst of a standing queue lands here)."""
        # ckeys are unique (seq tie-break), so plain tuple merge never
        # falls through to comparing the payload
        new = sorted(((e[0], e[1]), e[2]) for e in entries)
        old = []
        append = old.append
        node = self._head[2][0]
        while node is not None:
            append((node[0], node[1]))
            node = node[2][0]
        merged = list(heapq.merge(old, new)) if old else new
        level = 1
        self._head = [None, None, [None] * self.MAX_LEVEL]
        last = [self._head] * self.MAX_LEVEL
        for i, (ckey, req) in enumerate(merged, 1):
            h = min(self.MAX_LEVEL, (i & -i).bit_length())
            level = max(level, h)
            node = [ckey, req, [None] * h]
            for lvl in range(h):
                last[lvl][2][lvl] = node
                last[lvl] = node
        self._level = level
        del self._head[2][level:]

    @staticmethod
    def use_bulk(pending: int, indexed: int) -> bool:
        """The rebuild walks the whole chain (O(n)), while per-item
        inserts cost O(m log n) with no memmove — only batches comparable
        to the standing queue amortize the walk."""
        return pending > 64 and pending * 8 >= indexed

    def reqs(self) -> List[Request]:
        out = []
        append = out.append
        node = self._head[2][0]
        while node is not None:
            append(node[1])
            node = node[2][0]
        return out


_INDEXES = {"list": _ListIndex, "skiplist": _SkipListIndex}


class OrderedQueue:
    """A request queue that preserves append order (what FCFS paths and
    stable-sort tie-breaks see) and a priority index kept in ``sort_queue``
    order without per-iteration re-sorts.

    The append-order backing is an insertion-ordered dict keyed by rid, so
    ``remove`` is O(1) — the previous list-subclass representation paid an
    O(n) identity scan (``list.remove``) per removal, which dominated
    batch-formation time on large standing queues. Iteration, ``len`` and
    truthiness behave like the old list view. Keys are assigned lazily at
    the first ``sorted_view`` after an append (the key needs ``now``); each
    keyed entry carries a monotone sequence number so equal keys order
    exactly like Python's stable sort over append order. ``index`` picks
    the priority-index structure (skip list by default; the legacy
    bisected list is retained for reference benchmarks/tests).
    """

    def __init__(self, is_gt: bool, index: str = "skiplist"):
        self.is_gt = is_gt
        self._seq = 0
        self._order: Dict[int, Request] = {}  # rid -> req, append order
        self._index = _INDEXES[index]()
        self._keyed: Dict[int, Tuple[Tuple, int]] = {}  # rid -> (key, seq)
        self._rekey: List[Tuple[float, int, int]] = []  # heap (t, seq, rid)
        self._pending: Dict[int, Request] = {}          # rid -> req
        self._view: Optional[List[Request]] = None

    # -- list-like interface -------------------------------------------- #
    def __iter__(self):
        return iter(self._order.values())

    def __len__(self) -> int:
        return len(self._order)

    def __contains__(self, req: Request) -> bool:
        return self._order.get(req.rid) is req

    def get(self, rid: int) -> Optional[Request]:
        """O(1) member lookup by rid (None when not queued) — what lets
        the scheduler's incremental min-demand heaps validate lazily."""
        return self._order.get(rid)

    def __repr__(self) -> str:
        return f"OrderedQueue({list(self._order.values())!r})"

    def append(self, req: Request) -> None:
        self._order[req.rid] = req
        self._pending[req.rid] = req

    def remove(self, req: Request) -> None:
        del self._order[req.rid]           # O(1) index-map removal
        self._view = None
        if self._pending.pop(req.rid, None) is not None:
            return
        key, seq = self._keyed.pop(req.rid)
        self._index.remove(key, seq)

    # -- priority view -------------------------------------------------- #
    def _insert(self, req: Request, now: float,
                seq: Optional[int] = None) -> None:
        key = order_key(req, now, self.is_gt)
        if seq is None:                    # re-keys keep their seq so ties
            seq = self._seq                # still break by append order
            self._seq += 1
        self._index.insert(key, seq, req)
        self._keyed[req.rid] = (key, seq)
        t_next = _next_bucket_change(req, key[0])
        if t_next < float("inf"):
            heapq.heappush(self._rekey, (t_next, seq, req.rid))

    def _bulk_key(self, now: float) -> None:
        """Key a large pending batch through the index's bulk path."""
        new = []
        for req in self._pending.values():
            key = order_key(req, now, self.is_gt)
            seq = self._seq
            self._seq += 1
            new.append([key, seq, req])
            self._keyed[req.rid] = (key, seq)
            t_next = _next_bucket_change(req, key[0])
            if t_next < float("inf"):
                heapq.heappush(self._rekey, (t_next, seq, req.rid))
        self._index.bulk_insert(new)
        self._pending.clear()

    def sorted_view(self, now: float) -> List[Request]:
        """The queue in ``sort_queue(queue, now)`` order (a fresh list —
        callers mutate their copy)."""
        if self._pending:
            self._view = None
            if self._index.use_bulk(len(self._pending), len(self._keyed)):
                self._bulk_key(now)
            else:
                for req in self._pending.values():
                    self._insert(req, now)
                self._pending.clear()
        while self._rekey and self._rekey[0][0] <= now:
            _, seq, rid = heapq.heappop(self._rekey)
            cur = self._keyed.get(rid)
            if cur is None or cur[1] != seq:
                continue                   # removed or re-appended since
            key = cur[0]
            req = self._order[rid]
            self._index.remove(key, seq)
            del self._keyed[rid]
            self._insert(req, now, seq=seq)
            self._view = None
        if self._view is None:
            self._view = self._index.reqs()
        return list(self._view)


def pick_fit(sorted_reqs: Sequence[Request], budget: int, now: float,
             is_gt: bool) -> Optional[int]:
    """Within the highest-priority (deadline, kvc) range, binary-search the
    task whose length best fits ``budget`` (§3.4 'binary search to find a
    task ... close to the required length'). Returns an index or None."""
    if not sorted_reqs:
        return None
    head = sorted_reqs[0]
    hk = order_key(head, now, is_gt)[:2]
    # the slice sharing the head's (deadline, kvc) buckets, ordered by
    # descending length -> find first entry with length <= budget
    lo, hi = 0, len(sorted_reqs)
    while lo < hi:
        mid = (lo + hi) // 2
        r = sorted_reqs[mid]
        if order_key(r, now, is_gt)[:2] != hk:
            hi = mid
            continue
        length = r.remaining_predicted if is_gt else r.prompt_len
        if length > budget:
            lo = mid + 1
        else:
            hi = mid
    if lo < len(sorted_reqs):
        r = sorted_reqs[lo]
        length = r.remaining_predicted if is_gt else r.prompt_len
        if length <= budget:
            return lo
    return None
