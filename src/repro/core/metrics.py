"""Simulation metrics: everything the paper's figures report."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from .request import Request


@dataclass
class IterSample:
    t: float
    dt: float
    forward_size: int
    prompt_tokens: int
    n_decode: int
    kvc_used_frac: float
    kvc_alloc_frac: float
    sched_time: float
    extra_time: float
    n_completed: int


@dataclass
class SimResult:
    name: str
    requests: List[Request]
    samples: List[IterSample]
    wall_time: float
    tfs: int
    n_alloc_failures: int = 0
    n_allocs: int = 0
    n_preempt_swap: int = 0
    n_preempt_free: int = 0
    n_underprov: int = 0
    n_reserve_rescues: int = 0

    # ------------------------------------------------------------------ #
    @property
    def completed(self) -> List[Request]:
        return [r for r in self.requests if r.t_complete is not None]

    @property
    def throughput_tokens(self) -> float:
        toks = sum(r.true_rl + r.prompt_len for r in self.completed)
        return toks / max(1e-9, self.wall_time)

    @property
    def throughput_reqs(self) -> float:
        return len(self.completed) / max(1e-9, self.wall_time)

    @property
    def goodput(self) -> float:
        """Requests per second that met their SLO (fig 12)."""
        return sum(r.met_slo for r in self.completed) / max(1e-9, self.wall_time)

    @property
    def mean_jct(self) -> float:
        c = self.completed
        return float(np.mean([r.jct for r in c])) if c else float("nan")

    @property
    def p95_jct(self) -> float:
        c = self.completed
        return float(np.percentile([r.jct for r in c], 95)) if c else float("nan")

    @property
    def normalized_latency(self) -> float:
        """Mean end-to-end latency / output length (fig 9, per vLLM defn)."""
        c = self.completed
        if not c:
            return float("nan")
        return float(np.mean([r.jct / max(1, r.true_rl) for r in c]))

    @property
    def ssr(self) -> float:
        c = self.completed
        return sum(r.met_slo for r in c) / max(1, len(c))

    @property
    def mean_tbt(self) -> float:
        """Time between tokens ≈ (completion - first token)/RL."""
        c = [r for r in self.completed if r.t_first_token is not None
             and r.true_rl > 1]
        if not c:
            return float("nan")
        return float(np.mean([(r.t_complete - r.t_first_token)
                              / max(1, r.true_rl - 1) for r in c]))

    # ---- time-weighted utilizations ------------------------------------ #
    def _tw(self, vals, dts) -> float:
        dts = np.asarray(dts)
        if dts.sum() <= 0:
            return float("nan")
        return float(np.average(np.asarray(vals), weights=dts))

    @property
    def kvc_utilization(self) -> float:
        return self._tw([s.kvc_used_frac for s in self.samples],
                        [s.dt for s in self.samples])

    @property
    def kvc_allocated(self) -> float:
        return self._tw([s.kvc_alloc_frac for s in self.samples],
                        [s.dt for s in self.samples])

    @property
    def gpu_utilization(self) -> float:
        """Forward-size / TFS, time-weighted (the paper's proxy)."""
        return self._tw([min(1.0, s.forward_size / max(1, self.tfs))
                         for s in self.samples],
                        [s.dt for s in self.samples])

    @property
    def mean_forward_size(self) -> float:
        return self._tw([s.forward_size for s in self.samples],
                        [s.dt for s in self.samples])

    @property
    def alloc_failure_rate(self) -> float:
        tot = self.n_allocs + self.n_alloc_failures
        return self.n_alloc_failures / max(1, tot)

    @property
    def sched_overhead_frac(self) -> float:
        tot = sum(s.dt + s.sched_time + s.extra_time for s in self.samples)
        sch = sum(s.sched_time for s in self.samples)
        return sch / max(1e-9, tot)

    # ---- JCT decomposition (fig 1e) ------------------------------------ #
    def jct_breakdown(self) -> Dict[str, float]:
        c = self.completed
        if not c:
            return {}
        return {
            "waiting": float(np.mean([r.waiting_time for r in c])),
            "gt_queue": float(np.mean([r.gt_queue_time for r in c])),
            "exec": float(np.mean([r.exec_time for r in c])),
            "preempt": float(np.mean([r.preempt_time for r in c])),
            "sched": float(np.mean([r.sched_time for r in c])),
        }

    def completion_count_dist(self) -> Dict[int, int]:
        """Iterations by number of requests completed (fig 1f)."""
        out: Dict[int, int] = {}
        for s in self.samples:
            out[s.n_completed] = out.get(s.n_completed, 0) + 1
        return out

    def summary(self) -> Dict[str, float]:
        return {
            "throughput_tok_s": self.throughput_tokens,
            "throughput_req_s": self.throughput_reqs,
            "goodput_req_s": self.goodput,
            "mean_jct_s": self.mean_jct,
            "p95_jct_s": self.p95_jct,
            "norm_latency_s_per_tok": self.normalized_latency,
            "ssr": self.ssr,
            "mean_tbt_s": self.mean_tbt,
            "kvc_util": self.kvc_utilization,
            "kvc_alloc": self.kvc_allocated,
            "gpu_util": self.gpu_utilization,
            "fwd_size": self.mean_forward_size,
            "alloc_fail_rate": self.alloc_failure_rate,
            "sched_overhead": self.sched_overhead_frac,
            "preempt_swap": float(self.n_preempt_swap),
            "preempt_free": float(self.n_preempt_free),
            "underprov": float(self.n_underprov),
            "completed": float(len(self.completed)),
        }
