"""Response-length (RL) prediction (§2.3 / §3.3.2).

The paper fine-tunes OPT-13B à la Zheng et al. [23]; offline we provide:

  * ``OraclePredictor``   — ground truth (the paper's "Oracle" variant).
  * ``NoisyPredictor``    — bucket-accurate with a calibrated probability
    (matched to the paper's 77.5% / 73.2% / 69.8% sweet-spot accuracies),
    lognormal bucket error otherwise. Default for experiments.
  * ``LearnedPredictor``  — a small JAX MLP over prompt features, trained
    with the framework's own optimizer; demonstrates the full pipeline.

All predictors return a *bucketed* RL (multiple of ``bucket``), which is
what makes time-synced same-RL grouping effective (O2).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import numpy as np

from .request import Request

DEFAULT_BUCKET = 32


def bucketize(rl: float, bucket: int = DEFAULT_BUCKET) -> int:
    return max(bucket, int(math.ceil(rl / bucket)) * bucket)


class OraclePredictor:
    name = "oracle"

    def __init__(self, bucket: int = DEFAULT_BUCKET):
        self.bucket = bucket

    def predict(self, req: Request) -> int:
        return bucketize(req.true_rl, self.bucket)


class NoisyPredictor:
    """Bucket-correct with prob ``accuracy``; otherwise off by a lognormal
    multiplicative factor (under-prediction slightly more likely, matching
    Figure 5a's under/over-provisioning split)."""
    name = "noisy"

    def __init__(self, accuracy: float = 0.75, bucket: int = DEFAULT_BUCKET,
                 seed: int = 0, under_bias: float = 0.10):
        self.accuracy = accuracy
        self.bucket = bucket
        self.under_bias = under_bias
        self.rng = np.random.default_rng(seed)

    def predict(self, req: Request) -> int:
        if self.rng.random() < self.accuracy:
            return bucketize(req.true_rl, self.bucket)
        # miss: multiplicative lognormal error, biased slightly low
        err = self.rng.lognormal(-self.under_bias, 0.35)
        return bucketize(req.true_rl * err, self.bucket)


class LearnedPredictor:
    """Tiny MLP over prompt features. Feature vector: [log prompt_len, 1].

    Trained offline (fit) with plain numpy gradient descent — prediction has
    to be cheap and dependency-free inside the scheduler loop; the JAX
    training path lives in repro.training and is exercised by tests.
    """
    name = "learned"

    def __init__(self, bucket: int = DEFAULT_BUCKET, hidden: int = 16,
                 seed: int = 0):
        self.bucket = bucket
        rng = np.random.default_rng(seed)
        self.w1 = rng.normal(0, 0.5, (2, hidden))
        self.b1 = np.zeros(hidden)
        self.w2 = rng.normal(0, 0.5, (hidden, 1))
        self.b2 = np.zeros(1)

    @staticmethod
    def _features(prompt_lens: np.ndarray) -> np.ndarray:
        x = np.log(np.maximum(prompt_lens, 1.0))
        return np.stack([x, np.ones_like(x)], axis=-1)

    def _forward(self, X):
        h = np.tanh(X @ self.w1 + self.b1)
        return h, (h @ self.w2 + self.b2)[:, 0]

    def fit(self, requests: Sequence[Request], epochs: int = 300,
            lr: float = 0.05) -> float:
        X = self._features(np.array([r.prompt_len for r in requests], float))
        y = np.log(np.array([r.true_rl for r in requests], float))
        for _ in range(epochs):
            h, pred = self._forward(X)
            err = pred - y                       # (N,)
            g2 = h.T @ err / len(y)
            gb2 = err.mean()
            dh = np.outer(err, self.w2[:, 0]) * (1 - h * h)
            g1 = X.T @ dh / len(y)
            gb1 = dh.mean(axis=0)
            self.w2 -= lr * g2[:, None]
            self.b2 -= lr * gb2
            self.w1 -= lr * g1
            self.b1 -= lr * gb1
        _, pred = self._forward(X)
        return float(np.mean((pred - y) ** 2))

    def predict(self, req: Request) -> int:
        X = self._features(np.array([req.prompt_len], float))
        _, pred = self._forward(X)
        return bucketize(float(np.exp(pred[0])), self.bucket)


def apply_padding(predicted: int, pad_ratio: float,
                  bucket: int = DEFAULT_BUCKET) -> int:
    """Sweet-spot padding (O4): allocate predicted * (1 + pad_ratio)."""
    return bucketize(predicted * (1.0 + pad_ratio), bucket)


def annotate(requests: Sequence[Request], predictor, pad_ratio: float,
             bucket: int = DEFAULT_BUCKET) -> None:
    for r in requests:
        r.predicted_rl = predictor.predict(r)
        r.padded_rl = apply_padding(r.predicted_rl, pad_ratio, bucket)
