"""Request / task model for the serving scheduler.

A request goes through a prompt-processing task (PT) and a generation task
(GT). Timestamps follow the paper's JCT decomposition (§2.2): waiting,
scheduling, execution, preemption (+ GT queuing, which EconoServe excludes
from "execution").
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional


class State(enum.Enum):
    QUEUED_PT = "queued_pt"          # prompt waiting
    RUNNING_PT = "running_pt"        # prompt (chunk) executing
    QUEUED_GT = "queued_gt"          # generation waiting (holds prompt KVC)
    RUNNING_GT = "running_gt"
    PREEMPTED = "preempted"          # paused; may or may not hold KVC
    COMPLETED = "completed"
    ABORTED = "aborted"              # cancelled (deadline, crash, user)


@dataclass(eq=False)          # identity equality: queue membership tests and
class Request:                # removals must not deep-compare every field
    rid: int
    prompt_len: int
    true_rl: int                     # ground-truth response length
    arrival: float
    slo_deadline: float = float("inf")

    # --- prediction / allocation ---------------------------------------
    predicted_rl: int = 0            # raw predictor output (bucketed)
    padded_rl: int = 0               # predicted + sweet-spot padding
    alloc_rl: int = 0                # tokens of RL-space currently allocated

    # --- dynamic state ---------------------------------------------------
    state: State = State.QUEUED_PT
    generated: int = 0               # response tokens produced so far
    prompt_done: int = 0             # prompt tokens processed (chunking)
    occupied_kvc: int = 0            # tokens of KVC currently held
    hosted: bool = False             # running inside lent KVC (KVCPipe)

    # --- accounting -------------------------------------------------------
    t_start_exec: Optional[float] = None
    t_first_token: Optional[float] = None
    t_complete: Optional[float] = None
    waiting_time: float = 0.0
    gt_queue_time: float = 0.0
    exec_time: float = 0.0
    preempt_time: float = 0.0
    sched_time: float = 0.0
    swap_time: float = 0.0
    n_preemptions: int = 0
    n_alloc_failures: int = 0
    _last_event_t: float = 0.0

    def __post_init__(self):
        self._last_event_t = self.arrival

    # ------------------------------------------------------------------ #
    @property
    def remaining_rl(self) -> int:
        return max(0, self.true_rl - self.generated)

    @property
    def remaining_predicted(self) -> int:
        return max(0, self.padded_rl - self.generated)

    @property
    def done(self) -> bool:
        return self.generated >= self.true_rl

    @property
    def jct(self) -> float:
        assert self.t_complete is not None
        return self.t_complete - self.arrival

    @property
    def met_slo(self) -> bool:
        return self.t_complete is not None and self.t_complete <= self.slo_deadline

    def charge(self, t: float) -> None:
        """Attribute the elapsed interval to the current state's bucket."""
        dt = max(0.0, t - self._last_event_t)
        if self.state == State.QUEUED_PT:
            self.waiting_time += dt
        elif self.state == State.QUEUED_GT:
            self.gt_queue_time += dt
        elif self.state in (State.RUNNING_PT, State.RUNNING_GT):
            self.exec_time += dt
        elif self.state == State.PREEMPTED:
            self.preempt_time += dt
        self._last_event_t = t

    def set_state(self, state: State, t: float) -> None:
        self.charge(t)
        self.state = state
