"""Analytic iteration cost model for the discrete-event simulator.

Roofline-style: an iteration processing P prompt tokens and a set of decode
tokens (one per running GT, each attending its context) costs

    t = t_fix + max(flops / peak_flops, bytes / hbm_bw)

with weight bytes counted once per iteration (they are streamed for any
batch) and KV bytes per decode token proportional to its context. This
reproduces the qualitative regimes the paper relies on: prefill is
compute-bound, decode is memory-bound, and batching decode tokens amortizes
the weight stream (why TFS matters).

Two hardware profiles ship: the paper's A100-80GB, and TPU v5e (the
deployment target of this framework).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float          # FLOP/s (bf16)
    hbm_bw: float              # bytes/s
    swap_bw: float             # device<->host bytes/s (PCIe / PCIe-like)
    link_bw: float             # inter-device bytes/s (for KV transfer)
    t_fix: float = 8e-4        # per-iteration fixed overhead (s)


# swap_bw is the *effective* KV swap bandwidth, not raw PCIe: paged KV lives
# in non-contiguous blocks, and the vLLM-0.2-era swap path the paper measures
# does synchronous per-block copies (fig 1e: preemption = 20% of vLLM's JCT).
A100 = Hardware("a100", peak_flops=312e12, hbm_bw=2.0e12,
                swap_bw=2.5e9, link_bw=12.5e9)       # 100 Gb/s Ethernet
TPU_V5E = Hardware("tpu_v5e", peak_flops=197e12, hbm_bw=819e9,
                   swap_bw=2.0e9, link_bw=50e9)


@dataclass(frozen=True)
class ModelProfile:
    """What the cost model needs to know about the served model."""
    name: str
    n_params: float            # total parameters
    n_active: float            # active per token (MoE)
    n_layers: int
    kv_bytes_per_token: int    # across all layers
    d_model: int

    @staticmethod
    def from_config(cfg) -> "ModelProfile":
        hd = cfg.resolved_head_dim
        kvb = cfg.num_layers * 2 * cfg.num_kv_heads * hd * 2  # bf16
        n = _param_count(cfg)
        return ModelProfile(cfg.name, n_params=n["total"],
                            n_active=n["active"], n_layers=cfg.num_layers,
                            kv_bytes_per_token=kvb, d_model=cfg.d_model)


def _param_count(cfg) -> dict:
    """Storage ('total'), per-token-active ('active'), and per-token
    *compute* ('compute': counts shared-attention blocks once per
    invocation) parameter counts, covering every block kind."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    attn = d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
    dense_mlp = 3 * d * cfg.d_ff if cfg.d_ff else 0
    moe = moe_active = 0
    if cfg.is_moe:
        ff = cfg.moe_d_ff or cfg.d_ff
        moe = cfg.num_experts * 3 * d * ff
        moe_active = cfg.experts_per_token * 3 * d * ff

    pattern = cfg.pattern()
    per_kind = {}
    if "A" in pattern:
        mlp_part = (moe + dense_mlp) if cfg.is_moe else dense_mlp
        mlp_act = (moe_active + dense_mlp) if cfg.is_moe else dense_mlp
        per_kind["A"] = (attn + mlp_part, attn + mlp_act)
    if "M" in pattern:
        di, n, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        m = d * (2 * di + 2 * n + nh) + di * d \
            + cfg.ssm_conv_width * (di + 2 * n)
        per_kind["M"] = (m, m)
    if "X" in pattern or "S" in pattern:
        di = int(cfg.xlstm_proj_factor * d)
        x_p = 4 * d * di + di * d                      # q,k,v,o + down
        s_p = 4 * d * di + di * d + cfg.num_heads \
            * (di // cfg.num_heads) * 4 * (di // cfg.num_heads)
        per_kind["X"] = (x_p, x_p)
        per_kind["S"] = (s_p, s_p)

    total = active = 0
    for ch in pattern:
        t, a = per_kind[ch]
        total += t
        active += a
    # Zamba2-style shared attention: stored once, computed every invocation
    compute = active
    if cfg.shared_attention_every:
        kvh = cfg.shared_attn_kv_heads or cfg.num_kv_heads
        shared = d * hd * (cfg.num_heads * 2 + kvh * 2) + 3 * d * cfg.d_ff
        n_inv = cfg.num_layers // cfg.shared_attention_every
        total += shared
        active += shared
        compute += shared * n_inv
    embed = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    total += embed
    active += embed
    compute += embed
    return {"total": float(total), "active": float(active),
            "compute": float(compute)}


# OPT-13B profile used throughout the paper's experiments
OPT_13B = ModelProfile("opt-13b", n_params=13e9, n_active=13e9, n_layers=40,
                       kv_bytes_per_token=40 * 2 * 40 * 128 * 2, d_model=5120)


@dataclass
class CostModel:
    hw: Hardware = A100
    model: ModelProfile = OPT_13B
    weight_dtype_bytes: int = 2

    # ------------------------------------------------------------------ #
    def iteration_time(self, prompt_tokens: int,
                       decode_contexts: Iterable[int]) -> float:
        ctxs = list(decode_contexts)
        tokens = prompt_tokens + len(ctxs)
        if tokens == 0:
            return 0.0
        flops = 2.0 * self.model.n_active * tokens
        # attention flops (quadratic prefill term is folded into per-token
        # context costs upstream; decode attention flops are tiny vs matmuls)
        weight_bytes = self.model.n_active * self.weight_dtype_bytes
        kv_bytes = self.model.kv_bytes_per_token * float(sum(ctxs))
        act_bytes = tokens * self.model.d_model * 2 * self.model.n_layers * 4
        t_compute = flops / self.hw.peak_flops
        t_mem = (weight_bytes + kv_bytes + act_bytes) / self.hw.hbm_bw
        return self.hw.t_fix + max(t_compute, t_mem)

    def prompt_time(self, prompt_len: int) -> float:
        return self.iteration_time(prompt_len, [])

    def token_time(self, context: int = 512) -> float:
        return self.iteration_time(0, [context])

    # ------------------------------------------------------------------ #
    def swap_time(self, tokens: int) -> float:
        """Offload (or restore) `tokens` of KV to/from host memory."""
        return tokens * self.model.kv_bytes_per_token / self.hw.swap_bw

    def swap_out_time(self, tokens: int) -> float:
        """Device→host leg only. The tiered ladder charges each direction
        where it happens (out at swap-out, in at swap-in) instead of the
        legacy 2x round-trip charged up front."""
        return self.swap_time(tokens)

    def swap_in_time(self, tokens: int) -> float:
        """Host→device leg only (restore of a host-offloaded image)."""
        return self.swap_time(tokens)

    def kv_transfer_time(self, tokens: int) -> float:
        """DistServe-style prefill→decode instance KV handoff."""
        return tokens * self.model.kv_bytes_per_token / self.hw.link_bw

    def recompute_time(self, tokens: int) -> float:
        """Offload-free preemption restore = re-prefill of prompt+generated."""
        return self.iteration_time(tokens, [])

    # ------------------------------------------------------------------ #
    # scheduling-time models (per batch formation), §2.2 / Figure 14
    def sched_time_fcfs(self, n_queued: int, n_selected: int) -> float:
        return 2e-5 + 1e-6 * n_selected

    def sched_time_quadratic(self, n_queued: int, n_selected: int) -> float:
        """MultiRes: O(n^2) Euclidean-distance matching."""
        return 2e-5 + 2.5e-7 * n_queued * max(1, n_selected)

    def sched_time_grouped(self, n_queued: int, n_selected: int) -> float:
        """EconoServe: priority queues + binary search."""
        import math
        return 3e-5 + 2e-6 * n_selected * max(1.0, math.log2(max(2, n_queued)))

    def sched_time_mlfq(self, n_queued: int, n_selected: int) -> float:
        """FastServe: multi-level feedback queue with demotions."""
        return 2e-5 + 6e-6 * n_queued


def tfs_for(hw: Hardware, model: ModelProfile,
            dtype_bytes: int = 2) -> int:
    """Target forward size: tokens where compute time overtakes the weight
    stream (MXU/SM saturation point), as FastGen/Sarathi pick it."""
    t_weights = model.n_active * dtype_bytes / hw.hbm_bw
    per_token_flop_time = 2.0 * model.n_active / hw.peak_flops
    tokens = t_weights / per_token_flop_time  # = peak_flops*bytes/(2*bw)
    # round up to a multiple of 64 for hardware alignment
    return int(-(-tokens // 64) * 64)
