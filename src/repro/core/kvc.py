"""Block-based KV-cache manager (the paper's allocation substrate).

Supports every allocation discipline the paper compares:
  * exact-allocation  (EconoServe/MultiRes: prompt + padded predicted RL)
  * max-allocation    (ORCA/FastServe/SRTF: prompt + model max RL)
  * block-allocation  (vLLM/Sarathi: one block at a time, can fail mid-run)

The EconoServe PT reserve (§3.3) is a *watermark*, not a physical
partition — blocks are fungible pages. GT-side allocations must leave
``reserve_target`` blocks effectively set aside; PT admissions may dip into
that set-aside (tracked by ``reserve_in_use``). When a PT-phase request is
scheduled as a GT, its reserve charge is released (pure bookkeeping), which
gives freed blocks first-dibs back to the reserve — the rolling budget that
lets EconoServe add PTs every iteration.

Accounting distinguishes *allocated* from *used* tokens: KVC utilization
(the paper's headline metric) is used/capacity; exact-allocation's gap
between the two is exactly what KVCPipe closes. Both are maintained as
running counters — the simulator reads them every iteration, so they must
be O(1), not O(#allocations).

The *swap ledger* tracks per-rid KV page images offloaded to host memory
(rung 2 of the pressure-degradation ladder: lending → host swap →
recompute → shed). The ledger holds token extents only — the actual page
bytes live engine-side — under a bounded ``host_pool_tokens`` budget.
Registering past the budget evicts the oldest unpinned images (those
requests degrade one rung, to recompute); pinned images (in-flight
swap-in) are never evicted. ``shrink`` models a live capacity squeeze:
blocks that cannot be removed immediately are parked in
``pending_shrink`` and harvested as allocations free.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional


class AllocationError(Exception):
    pass


def blocks_for(tokens: int, block_size: int) -> int:
    return -(-max(0, tokens) // block_size)


@dataclass
class Allocation:
    blocks: int = 0
    reserve_blocks: int = 0     # portion charged against the PT reserve
    used_tokens: int = 0
    lent_tokens: int = 0        # KVCPipe: capacity granted inside a host span


@dataclass
class SwapEntry:
    """One host-offloaded KV image: token extent + eviction protection."""
    tokens: int = 0
    pinned: bool = False        # in-flight swap-in: never evicted


class BlockKVC:
    def __init__(self, capacity_tokens: int, block_size: int = 32,
                 reserve_frac: float = 0.0,
                 host_pool_tokens: Optional[int] = None):
        self.block_size = block_size
        self.total_blocks = capacity_tokens // block_size
        self.reserve_target = int(self.total_blocks * reserve_frac)
        self.free_blocks = self.total_blocks
        self.reserve_in_use = 0
        self.allocs: Dict[int, Allocation] = {}
        self.n_failures = 0
        self.n_allocs = 0
        self._used_tokens = 0          # running sum of per-alloc used_tokens
        # -- host swap ledger (rung 2) --
        self.host_pool_tokens = (self.total_blocks * block_size
                                 if host_pool_tokens is None
                                 else int(host_pool_tokens))
        self.swapped: Dict[int, SwapEntry] = {}   # insertion order = age
        self.host_used = 0
        self.n_swap_outs = 0
        self.n_swap_ins = 0
        self.n_host_evictions = 0
        # -- live capacity squeeze --
        self.pending_shrink = 0        # blocks owed, harvested by free()
        self.n_shrinks = 0             # squeezes applied (gates rung-4 shed)

    # ------------------------------------------------------------------ #
    @property
    def capacity_tokens(self) -> int:
        return self.total_blocks * self.block_size

    @property
    def reserve_set_aside(self) -> int:
        """Blocks currently held back for PT admission."""
        return max(0, self.reserve_target - self.reserve_in_use)

    @property
    def free_general(self) -> int:
        """Blocks a GT-side allocation may take."""
        return max(0, self.free_blocks - self.reserve_set_aside)

    @property
    def free_reserve(self) -> int:
        """Reserve headroom a PT admission may take (bounded by real free)."""
        return min(self.reserve_set_aside, self.free_blocks)

    @property
    def allocated_blocks(self) -> int:
        return self.total_blocks - self.free_blocks

    @property
    def used_tokens(self) -> int:
        return self._used_tokens

    @property
    def utilization(self) -> float:
        return self.used_tokens / max(1, self.capacity_tokens)

    @property
    def allocated_frac(self) -> float:
        return self.allocated_blocks / max(1, self.total_blocks)

    def free_tokens(self) -> int:
        return self.free_general * self.block_size

    # ------------------------------------------------------------------ #
    # GT-side (general pool, respects the reserve watermark)
    # ------------------------------------------------------------------ #
    def can_allocate(self, tokens: int) -> bool:
        return blocks_for(tokens, self.block_size) <= self.free_general

    def allocate(self, rid: int, tokens: int) -> bool:
        """Exact/max allocation. All-or-nothing."""
        b = blocks_for(tokens, self.block_size)
        if b > self.free_general:
            self.n_failures += 1
            return False
        self.free_blocks -= b
        self.allocs.setdefault(rid, Allocation()).blocks += b
        self.n_allocs += 1
        return True

    def extend(self, rid: int, blocks: int = 1) -> bool:
        """vLLM-style incremental growth (counted as an allocation op)."""
        if blocks > self.free_general:
            self.n_failures += 1
            return False
        self.free_blocks -= blocks
        self.allocs.setdefault(rid, Allocation()).blocks += blocks
        self.n_allocs += 1
        return True

    # ------------------------------------------------------------------ #
    # PT-side (may dip into the reserve set-aside)
    # ------------------------------------------------------------------ #
    def allocate_reserve(self, rid: int, blocks: int = 1) -> bool:
        if blocks > self.free_reserve:
            return False
        self.free_blocks -= blocks
        self.reserve_in_use += blocks
        self.allocs.setdefault(rid, Allocation()).reserve_blocks += blocks
        return True

    def release_reserve(self, rid: int) -> None:
        """The request left the PT phase: stop charging its blocks to the
        reserve (pure bookkeeping; freed blocks will replenish it)."""
        a = self.allocs.get(rid)
        if a is None or a.reserve_blocks == 0:
            return
        self.reserve_in_use -= a.reserve_blocks
        a.blocks += a.reserve_blocks
        a.reserve_blocks = 0

    # ------------------------------------------------------------------ #
    def set_used(self, rid: int, tokens: int) -> None:
        a = self.allocs.get(rid)
        if a is not None:
            self._used_tokens += tokens - a.used_tokens
            a.used_tokens = tokens

    def add_used(self, rid: int, tokens: int = 1) -> None:
        a = self.allocs.get(rid)
        if a is not None:
            a.used_tokens += tokens
            self._used_tokens += tokens

    def allocated_tokens(self, rid: int) -> int:
        a = self.allocs.get(rid)
        return 0 if a is None else (a.blocks + a.reserve_blocks) * self.block_size

    def free(self, rid: int) -> int:
        """Release a request's allocation. Returns tokens freed."""
        a = self.allocs.pop(rid, None)
        if a is None:
            return 0
        self.free_blocks += a.blocks + a.reserve_blocks
        self.reserve_in_use -= a.reserve_blocks
        self._used_tokens -= a.used_tokens
        if self.pending_shrink:
            h = min(self.pending_shrink, self.free_blocks)
            self.free_blocks -= h
            self.total_blocks -= h
            self.pending_shrink -= h
        return (a.blocks + a.reserve_blocks) * self.block_size

    # ------------------------------------------------------------------ #
    # host swap ledger (pressure ladder rung 2)
    # ------------------------------------------------------------------ #
    def swap_register(self, rid: int, tokens: int) -> Optional[List[int]]:
        """Record a host-offloaded KV image of ``tokens`` extent.

        Returns the rids of older unpinned images evicted to make room
        (each degrades one rung, to recompute), or ``None`` when the
        image cannot fit the budget even after evicting everything
        unpinned — the caller must drop the image and recompute.
        """
        assert rid not in self.swapped, rid
        tokens = max(0, tokens)
        if tokens > self.host_pool_tokens:
            return None
        evicted: List[int] = []
        if self.host_used + tokens > self.host_pool_tokens:
            freed = 0
            for old_rid, e in self.swapped.items():
                if e.pinned:
                    continue
                evicted.append(old_rid)
                freed += e.tokens
                if self.host_used - freed + tokens <= self.host_pool_tokens:
                    break
            if self.host_used - freed + tokens > self.host_pool_tokens:
                return None            # everything left is pinned
            for old_rid in evicted:    # fits: commit the evictions
                self.host_used -= self.swapped.pop(old_rid).tokens
                self.n_host_evictions += 1
        self.swapped[rid] = SwapEntry(tokens=tokens)
        self.host_used += tokens
        self.n_swap_outs += 1
        return evicted

    def swap_release(self, rid: int, restored: bool = False) -> int:
        """Drop a ledger entry (image restored, dropped, or request done).
        Returns the tokens released; counts a swap-in when ``restored``."""
        e = self.swapped.pop(rid, None)
        if e is None:
            return 0
        self.host_used -= e.tokens
        if restored:
            self.n_swap_ins += 1
        return e.tokens

    def swap_pin(self, rid: int) -> None:
        e = self.swapped.get(rid)
        if e is not None:
            e.pinned = True

    def swap_unpin(self, rid: int) -> None:
        e = self.swapped.get(rid)
        if e is not None:
            e.pinned = False

    def swapped_tokens(self, rid: int) -> int:
        e = self.swapped.get(rid)
        return 0 if e is None else e.tokens

    # ------------------------------------------------------------------ #
    def shrink(self, tokens: int) -> int:
        """Live capacity squeeze (chaos ``squeeze`` event): remove up to
        ``tokens`` worth of blocks. Blocks still held by allocations are
        owed — parked in ``pending_shrink`` and harvested as requests
        free. Returns blocks removed immediately. Never invalidates a
        no-admission certificate: capacity only shrinks."""
        want = blocks_for(tokens, self.block_size)
        now = min(want, self.free_blocks)
        self.free_blocks -= now
        self.total_blocks -= now
        self.pending_shrink += want - now
        self.reserve_target = max(self.reserve_in_use,
                                  min(self.reserve_target, self.total_blocks))
        self.n_shrinks += 1
        return now

    # ------------------------------------------------------------------ #
    def publish_metrics(self, registry, **labels) -> None:
        """Publish the cache's block/token accounting into a
        ``repro.obs`` registry (names: ``kvc_<noun>_<unit>``)."""
        ln = tuple(sorted(labels))

        def c(name, help, value):
            registry.counter(name, help, ln).labels(**labels).inc_to(value)

        def g(name, help, value):
            registry.gauge(name, help, ln).labels(**labels).set(value)

        g("kvc_total_blocks", "current capacity in blocks",
          self.total_blocks)
        g("kvc_free_blocks", "blocks free", self.free_blocks)
        g("kvc_occupied_blocks", "blocks held by live allocations",
          self.allocated_blocks)
        g("kvc_used_tokens", "tokens actually written", self.used_tokens)
        g("kvc_allocated_frac", "allocated / total blocks",
          self.allocated_frac)
        g("kvc_utilization_frac", "used tokens / capacity (the paper's "
          "headline metric)", self.utilization)
        g("kvc_reserve_in_use_blocks", "PT-reserve blocks charged",
          self.reserve_in_use)
        g("kvc_reserve_target_blocks", "PT-reserve watermark",
          self.reserve_target)
        c("kvc_allocs_total", "allocation operations", self.n_allocs)
        c("kvc_alloc_failures_total", "runtime allocation failures "
          "(Table 1)", self.n_failures)
        c("kvc_swap_outs_total", "KV images registered to the host pool",
          self.n_swap_outs)
        c("kvc_swap_ins_total", "KV images restored from the host pool",
          self.n_swap_ins)
        c("kvc_host_evictions_total", "host-pool images evicted to fit "
          "newer captures", self.n_host_evictions)
        g("kvc_host_pool_used_tokens", "host-pool tokens in use",
          self.host_used)
        g("kvc_host_pool_budget_tokens", "host-pool budget",
          self.host_pool_tokens)
        g("kvc_pending_shrink_blocks", "squeeze debt harvested as "
          "allocations free", self.pending_shrink)
        c("kvc_shrinks_total", "live capacity squeezes applied",
          self.n_shrinks)

    # ------------------------------------------------------------------ #
    def check_invariants(self) -> None:
        held = sum(a.blocks + a.reserve_blocks for a in self.allocs.values())
        assert self.free_blocks + held == self.total_blocks, \
            (self.free_blocks, held, self.total_blocks)
        res_held = sum(a.reserve_blocks for a in self.allocs.values())
        assert res_held == self.reserve_in_use, \
            (res_held, self.reserve_in_use)
        used_held = sum(a.used_tokens for a in self.allocs.values())
        assert used_held == self._used_tokens, \
            (used_held, self._used_tokens)
        assert 0 <= self.free_blocks <= self.total_blocks
        assert 0 <= self.reserve_in_use <= self.reserve_target
        for rid, a in self.allocs.items():
            assert a.used_tokens <= (a.blocks + a.reserve_blocks) \
                * self.block_size + a.lent_tokens, rid
        host_held = sum(e.tokens for e in self.swapped.values())
        assert host_held == self.host_used, (host_held, self.host_used)
        assert 0 <= self.host_used <= self.host_pool_tokens, \
            (self.host_used, self.host_pool_tokens)
        assert self.pending_shrink >= 0, self.pending_shrink
        assert self.total_blocks >= 0, self.total_blocks
