"""Scheduler factory + one-call comparison harness."""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from . import baselines, predictor, simulator, traces
from .costmodel import CostModel
from .metrics import SimResult
from .request import Request
from .scheduler import SchedulerConfig, make_econoserve

SCHEDULERS = ("orca", "srtf", "fastserve", "vllm", "sarathi", "multires",
              "synccoupled", "econoserve-d", "econoserve-sd",
              "econoserve-sdo", "econoserve", "oracle", "distserve")


def make_scheduler(name: str, cfg: SchedulerConfig, cost: CostModel):
    if name == "orca":
        return baselines.OrcaScheduler(cfg, cost)
    if name == "srtf":
        return baselines.SRTFScheduler(cfg, cost)
    if name == "fastserve":
        return baselines.FastServeScheduler(cfg, cost)
    if name == "vllm":
        return baselines.VLLMScheduler(cfg, cost)
    if name == "sarathi":
        return baselines.SarathiScheduler(cfg, cost)
    if name == "multires":
        return baselines.MultiResScheduler(cfg, cost)
    if name == "synccoupled":
        return baselines.SyncCoupledScheduler(cfg, cost)
    if name.startswith("econoserve"):
        variant = {"econoserve": "full", "econoserve-d": "d",
                   "econoserve-sd": "sd", "econoserve-sdo": "sdo"}[name]
        return make_econoserve(cfg, cost, variant)
    if name == "oracle":
        return make_econoserve(cfg, cost, "oracle")
    raise ValueError(name)


def needs_oracle_rl(name: str) -> bool:
    return name in ("oracle", "srtf")


def run_one(name: str, requests: Sequence[Request],
            cfg: Optional[SchedulerConfig] = None,
            cost: Optional[CostModel] = None,
            pad_ratio: float = 0.15, accuracy: float = 0.75,
            seed: int = 0, max_iters: int = 2_000_000) -> SimResult:
    """Clone requests, annotate predictions, simulate one scheduler."""
    import copy
    cfg = cfg or SchedulerConfig()
    cost = cost or CostModel()
    reqs = copy.deepcopy(list(requests))
    if needs_oracle_rl(name):
        pred = predictor.OraclePredictor(cfg.bucket)
        predictor.annotate(reqs, pred, 0.0, cfg.bucket)
    else:
        pred = predictor.NoisyPredictor(accuracy=accuracy, bucket=cfg.bucket,
                                        seed=seed)
        predictor.annotate(reqs, pred, pad_ratio, cfg.bucket)
    if name == "distserve":
        return baselines.simulate_distserve(reqs, cfg, cost,
                                            max_iters=max_iters)
    sched = make_scheduler(name, cfg, cost)
    return simulator.simulate(reqs, sched, cost, max_iters=max_iters)


def compare(names: Sequence[str], requests: Sequence[Request],
            cfg: Optional[SchedulerConfig] = None,
            cost: Optional[CostModel] = None,
            **kw) -> Dict[str, SimResult]:
    return {n: run_one(n, requests, cfg, cost, **kw) for n in names}


def run_cluster(name: str, requests: Sequence[Request],
                n_instances: int = 2, router: str = "least-kvc",
                roles: Optional[Sequence[str]] = None,
                cfg: Optional[SchedulerConfig] = None,
                cost: Optional[CostModel] = None,
                pad_ratio: float = 0.15, accuracy: float = 0.75,
                seed: int = 0, max_iters: int = 2_000_000,
                autoscaler=None):
    """Clone + annotate requests, then serve the stream across
    ``n_instances`` instances of scheduler ``name`` under a ClusterSim
    (optionally with disaggregated ``roles``, e.g. ("prefill", "decode")
    for a DistServe-style configuration). Each instance gets its own KVC
    of ``cfg.kvc_tokens`` — n instances model n GPUs."""
    import copy

    # imported lazily: repro.cluster builds on repro.core
    from repro.cluster.sim import ClusterSim

    cfg = cfg or SchedulerConfig()
    cost = cost or CostModel()
    reqs = copy.deepcopy(list(requests))
    if needs_oracle_rl(name):
        pred = predictor.OraclePredictor(cfg.bucket)
        predictor.annotate(reqs, pred, 0.0, cfg.bucket)
    else:
        pred = predictor.NoisyPredictor(accuracy=accuracy, bucket=cfg.bucket,
                                        seed=seed)
        predictor.annotate(reqs, pred, pad_ratio, cfg.bucket)
    cs = ClusterSim(lambda i: make_scheduler(name, cfg, cost), cost,
                    n_instances=n_instances, router=router, roles=roles,
                    seed=seed, autoscaler=autoscaler)
    return cs.run(reqs, max_iters=max_iters)
