"""Watermark-guarded KVC pressure controller.

``WatermarkGuard`` turns raw KVC occupancy into a stable two-state
backpressure signal: an :class:`EWMA` smooths the per-step occupancy,
and high/low watermarks with hysteresis (plus a patience count on the
way up) decide when the engine should proactively swap waiting GTs out
to the host pool versus release them back for admission. Hysteresis is
what keeps the ladder from thrashing — a single controller decision
covers the whole span between the watermarks.

The controller is deterministic: state depends only on the sequence of
observed occupancies, and the engine only feeds it at megastep-window
boundaries (occupancy is frozen inside a certified window), so a K=8
fused run observes exactly the same sequence as a K=1 run.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class EWMA:
    """Exponentially-weighted moving average, seeded by first sample."""
    alpha: float = 0.5
    value: float = 0.0
    _primed: bool = False

    def update(self, x: float) -> float:
        if not self._primed:
            self.value = float(x)
            self._primed = True
        else:
            self.value += self.alpha * (float(x) - self.value)
        return self.value


@dataclass
class WatermarkGuard:
    """Hysteresis state machine over EWMA'd KVC occupancy.

    ``observe(frac)`` returns the current state: ``True`` means the
    guard is in *pressure* mode (swap out, hold admissions), ``False``
    means relaxed (swap back in). Entry requires the smoothed occupancy
    to sit above ``high`` for ``patience`` consecutive observations;
    exit requires it to fall below ``low`` (no patience on the way
    down — releasing pressure late is the expensive direction).
    """
    high: float = 0.92
    low: float = 0.70
    alpha: float = 0.5
    patience: int = 2
    ewma: EWMA = field(default_factory=EWMA)
    pressure: bool = False
    _over: int = 0              # consecutive observations above high
    n_trips: int = 0            # relaxed -> pressure transitions
    n_releases: int = 0         # pressure -> relaxed transitions

    def __post_init__(self):
        assert 0.0 <= self.low <= self.high <= 1.0, (self.low, self.high)
        self.ewma.alpha = self.alpha

    def observe(self, occupied_frac: float) -> bool:
        v = self.ewma.update(occupied_frac)
        if not self.pressure:
            if v >= self.high:
                self._over += 1
                if self._over >= self.patience:
                    self.pressure = True
                    self.n_trips += 1
            else:
                self._over = 0
        elif v <= self.low:
            self.pressure = False
            self._over = 0
            self.n_releases += 1
        return self.pressure
