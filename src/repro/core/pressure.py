"""Watermark-guarded KVC pressure controller.

``WatermarkGuard`` turns raw KVC occupancy into a stable two-state
backpressure signal: an :class:`EWMA` smooths the per-step occupancy,
and high/low watermarks with hysteresis (plus a patience count on the
way up) decide when the engine should proactively swap waiting GTs out
to the host pool versus release them back for admission. Hysteresis is
what keeps the ladder from thrashing — a single controller decision
covers the whole span between the watermarks.

The controller is deterministic: state depends only on the sequence of
observed occupancies, and the engine only feeds it at megastep-window
boundaries (occupancy is frozen inside a certified window), so a K=8
fused run observes exactly the same sequence as a K=1 run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class EWMA:
    """Exponentially-weighted moving average, seeded by first sample."""
    alpha: float = 0.5
    value: float = 0.0
    _primed: bool = False

    def update(self, x: float) -> float:
        if not self._primed:
            self.value = float(x)
            self._primed = True
        else:
            self.value += self.alpha * (float(x) - self.value)
        return self.value


@dataclass
class WatermarkGuard:
    """Hysteresis state machine over EWMA'd KVC occupancy.

    ``observe(frac)`` returns the current state: ``True`` means the
    guard is in *pressure* mode (swap out, hold admissions), ``False``
    means relaxed (swap back in). Entry requires the smoothed occupancy
    to sit above ``high`` for ``patience`` consecutive observations;
    exit requires it to fall below ``low`` (no patience on the way
    down — releasing pressure late is the expensive direction).
    """
    high: float = 0.92
    low: float = 0.70
    alpha: float = 0.5
    patience: int = 2
    ewma: EWMA = field(default_factory=EWMA)
    pressure: bool = False
    _over: int = 0              # consecutive observations above high
    n_trips: int = 0            # relaxed -> pressure transitions
    n_releases: int = 0         # pressure -> relaxed transitions

    def __post_init__(self):
        assert 0.0 <= self.low <= self.high <= 1.0, (self.low, self.high)
        self.ewma.alpha = self.alpha

    def observe(self, occupied_frac: float) -> bool:
        v = self.ewma.update(occupied_frac)
        if not self.pressure:
            if v >= self.high:
                self._over += 1
                if self._over >= self.patience:
                    self.pressure = True
                    self.n_trips += 1
            else:
                self._over = 0
        elif v <= self.low:
            self.pressure = False
            self._over = 0
            self.n_releases += 1
        return self.pressure


@dataclass
class RollingQuantile:
    """Windowed quantile smoothed by an :class:`EWMA` — the same
    deterministic smoothing idiom :class:`WatermarkGuard` uses for KVC
    occupancy, applied to latency samples. ``value()`` is None until
    ``min_samples`` observations arrived: a cold estimator must never
    produce a threshold (the consumer treats None as "no verdict")."""
    q: float = 0.9
    window: int = 64
    min_samples: int = 4
    alpha: float = 0.5
    samples: List[float] = field(default_factory=list)
    ewma: EWMA = field(default_factory=EWMA)
    n_observed: int = 0

    def __post_init__(self):
        assert 0.0 < self.q <= 1.0, self.q
        self.ewma.alpha = self.alpha

    def observe(self, x: float) -> None:
        self.n_observed += 1
        self.samples.append(float(x))
        if len(self.samples) > self.window:
            del self.samples[:len(self.samples) - self.window]
        s = sorted(self.samples)
        k = min(len(s) - 1, int(self.q * len(s)))
        self.ewma.update(s[k])

    def value(self) -> Optional[float]:
        if self.n_observed < self.min_samples:
            return None
        return self.ewma.value


class StragglerWatchdog:
    """Per-request progress watchdog: TTFT-stall and token-rate stall.

    The cluster backends feed it host-visible progress (tokens drained
    to the client record, on the backend's iteration/event clock) and
    completed-stream latency samples; ``stalled(key, now)`` answers
    whether a tracked request has gone quiet long enough to justify a
    hedge clone. Thresholds are ``factor`` multiples of a rolling
    EWMA-smoothed quantile of *observed* latencies (TTFT for requests
    that have not produced a first token, inter-token gap for ones
    mid-decode), floored by ``floor`` so a cold or noisy estimate never
    produces a hair-trigger hedge. With no samples yet there is no
    threshold and no verdict — a fresh fleet never hedges.

    Deterministic: state depends only on the observation sequence, so a
    seeded chaos run reproduces its hedge decisions bit-for-bit.
    """

    def __init__(self, ttft_factor: float = 3.0, rate_factor: float = 3.0,
                 quantile: float = 0.9, window: int = 64,
                 min_samples: int = 4, floor: float = 4.0,
                 alpha: float = 0.5):
        self.ttft_factor = ttft_factor
        self.rate_factor = rate_factor
        self.floor = floor
        self._ttft = RollingQuantile(q=quantile, window=window,
                                     min_samples=min_samples, alpha=alpha)
        self._gap = RollingQuantile(q=quantile, window=window,
                                    min_samples=min_samples, alpha=alpha)
        # key -> (t_started, tokens_seen, t_last_progress)
        self._prog: Dict[object, Tuple[float, int, float]] = {}
        self.n_stall_verdicts = 0

    # -- tracking ------------------------------------------------------- #
    def track(self, key, now: float) -> None:
        """Start (or restart) watching one request from ``now``."""
        self._prog[key] = (now, 0, now)

    def forget(self, key) -> None:
        self._prog.pop(key, None)

    def reset(self, key, tokens: int, now: float) -> None:
        """Re-arm the stall clocks after a re-route: progress so far is
        kept, the silence timer restarts — the new host deserves a full
        threshold window before being called a straggler."""
        self._prog[key] = (now, int(tokens), now)

    def tracked(self, key) -> bool:
        return key in self._prog

    def observe_progress(self, key, tokens: int, now: float) -> None:
        """Record host-visible progress: ``tokens`` drained so far. The
        first token closes the request's TTFT sample; each further token
        feeds the inter-token gap estimator (averaged over the tokens
        that arrived in the same drain batch)."""
        st = self._prog.get(key)
        if st is None:
            return
        t0, seen, t_last = st
        if tokens <= seen:
            return
        if seen == 0:
            self._ttft.observe(now - t0)
            seen_new = tokens
            if tokens > 1:
                self._gap.observe(0.0)   # batch-drained burst: zero gap
        else:
            self._gap.observe((now - t_last) / (tokens - seen))
            seen_new = tokens
        self._prog[key] = (t0, seen_new, now)

    # -- thresholds / verdicts ------------------------------------------ #
    def ttft_threshold(self) -> Optional[float]:
        v = self._ttft.value()
        return None if v is None else max(self.floor, self.ttft_factor * v)

    def gap_threshold(self) -> Optional[float]:
        v = self._gap.value()
        return None if v is None else max(self.floor, self.rate_factor * v)

    def stalled(self, key, now: float) -> Optional[str]:
        """``"ttft-stall"`` / ``"rate-stall"`` when the request's silence
        exceeds the current threshold, else None (including: not tracked,
        or thresholds still cold)."""
        st = self._prog.get(key)
        if st is None:
            return None
        t0, seen, t_last = st
        if seen == 0:
            thr = self.ttft_threshold()
            if thr is not None and now - t0 > thr:
                self.n_stall_verdicts += 1
                return "ttft-stall"
            return None
        thr = self.gap_threshold()
        if thr is not None and now - t_last > thr:
            self.n_stall_verdicts += 1
            return "rate-stall"
        return None
