"""EconoServe scheduler family (§3) on a shared single-engine substrate.

``BaseScheduler`` owns the mechanics every policy shares: queues, the block
KVC, iteration bookkeeping (token generation, PT→GT transition, completion,
preemption). Policies override batch formation.

The EconoServe variants map to the paper's ablation:
  EconoServe-D    decoupled PT/GT queues, exact-allocation, iteration-level
  EconoServe-SD   + time-synced same-RL groups
  EconoServe-SDO  + Ordering
  EconoServe      + KVC pipelining  (the full system)
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .costmodel import CostModel
from .kvc import Allocation, BlockKVC, blocks_for
from .ordering import OrderedQueue, order_key, pick_fit, sort_queue
from .pipelining import PipeBook
from .predictor import DEFAULT_BUCKET, bucketize
from .request import Request, State


@dataclass
class IterationPlan:
    prompt_items: List[Tuple[Request, int]] = field(default_factory=list)
    decode_reqs: List[Request] = field(default_factory=list)
    sched_time: float = 0.0
    extra_time: float = 0.0        # swap-in/out, KV transfer, ...

    @property
    def prompt_tokens(self) -> int:
        return sum(c for _, c in self.prompt_items)

    @property
    def forward_size(self) -> int:
        return self.prompt_tokens + len(self.decode_reqs)

    @property
    def empty(self) -> bool:
        return not self.prompt_items and not self.decode_reqs


@dataclass
class Group:
    key: int                      # synced (padded) remaining RL at formation
    members: List[Request] = field(default_factory=list)
    age: int = 0                  # iterations since the group started


@dataclass
class SchedulerConfig:
    kvc_tokens: int = 14_336
    block_size: int = 32
    tfs: int = 2048
    max_model_len: int = 2048     # max RL for max-allocation policies
    reserve_frac: float = 0.03
    pad_ratio: float = 0.15
    buffer_frac: float = 0.15     # KVCPipe buffer b, fraction of RL
    bucket: int = DEFAULT_BUCKET
    max_batch_reqs: int = 512
    # feature toggles (ablation)
    sync_groups: bool = True
    ordering: bool = True
    pipelining: bool = True
    offload_free: bool = True     # preemption style for under-provision
    # incremental queue index (OrderedQueue) instead of per-iteration full
    # re-sorts; batch decisions are identical either way (tested) — False
    # keeps the reference path for determinism checks and benchmarks
    incremental_queues: bool = True
    # priority-index structure inside OrderedQueue: "skiplist" (O(log n)
    # insert/remove) or the legacy bisected "list" (O(n) memmove each);
    # decisions are bitwise identical either way (tested)
    queue_index: str = "skiplist"


class BaseScheduler:
    name = "base"

    def __init__(self, cfg: SchedulerConfig, cost: CostModel):
        self.cfg = cfg
        self.cost = cost
        self.kvc = BlockKVC(cfg.kvc_tokens, cfg.block_size, cfg.reserve_frac)
        self.pt_queue: List[Request] = []
        self.gt_queue: List[Request] = []
        self.running_groups: List[Group] = []
        self.current_plan: Optional[IterationPlan] = None
        self.completed: List[Request] = []
        # events/stats
        self.group_completed = True     # trigger initial GT fill
        self.n_preempt_swap = 0
        self.n_preempt_free = 0
        self.n_underprov = 0
        self.n_reserve_rescues = 0
        self.n_hosted = 0
        self.pending_extra_time = 0.0
        self.iter_completion_counts: List[int] = []
        # watermark-guard backpressure: queued GTs swapped out to host and
        # held out of admission until the guard releases pressure
        self.swap_hold: Dict[int, Request] = {}
        self.n_guard_swaps = 0
        # pressure-ladder rung 4: requests a capacity squeeze made
        # permanently inadmissible, cancelled by form_batch's deadlock
        # relief and parked here for the backend to surface terminally
        self.infeasible_shed: List[Request] = []
        self.n_infeasible_shed = 0
        # incrementally-maintained queue-minimum-demand heaps (lazy):
        # entries (value, rid); stale/changed entries are discarded or
        # re-keyed at query time. Only EconoServe with an OrderedQueue
        # maintains them (the only policy with a KVC certificate).
        self._track_gt_demand = False
        self._gt_need_heap: List[Tuple[int, int]] = []      # need blocks
        self._gt_need_res_heap: List[Tuple[int, int]] = []  # resident only
        self._gt_host_heap: List[Tuple[int, int]] = []      # remaining RL

    # ---------------------------------------------------------------- #
    def publish_metrics(self, registry, **labels) -> None:
        """Publish queue/preemption/pressure counters into a
        ``repro.obs`` registry (names: ``scheduler_<noun>_<unit>``),
        then delegate the cache accounting to ``self.kvc``. One typed
        publication path shared by the engine sampler, the cluster
        backends and stall diagnostics."""
        ln = tuple(sorted(labels))

        def c(name, help, value, **extra):
            registry.counter(name, help, ln + tuple(sorted(extra))) \
                .labels(**labels, **extra).inc_to(value)

        def g(name, help, value, **extra):
            registry.gauge(name, help, ln + tuple(sorted(extra))) \
                .labels(**labels, **extra).set(value)

        g("scheduler_queue_depth", "requests waiting per queue",
          len(self.pt_queue), queue="pt")
        g("scheduler_queue_depth", "requests waiting per queue",
          len(self.gt_queue), queue="gt")
        g("scheduler_running_requests",
          "decode-phase requests in the current groups",
          sum(len(grp.members) for grp in self.running_groups))
        g("scheduler_running_groups", "time-synced RL groups",
          len(self.running_groups))
        g("scheduler_swap_hold_requests",
          "queued GTs held out of admission by the watermark guard",
          len(self.swap_hold))
        c("scheduler_completed_total", "requests completed",
          len(self.completed))
        c("scheduler_preemptions_total", "preemptions by style",
          self.n_preempt_swap, kind="swap")
        c("scheduler_preemptions_total", "preemptions by style",
          self.n_preempt_free, kind="free")
        c("scheduler_underprovision_total",
          "iterations that under-provisioned a group", self.n_underprov)
        c("scheduler_reserve_rescues_total",
          "PT admissions funded from the reserve set-aside",
          self.n_reserve_rescues)
        c("scheduler_hosted_total",
          "requests run inside lent KVC (KVCPipe)", self.n_hosted)
        c("scheduler_guard_swaps_total",
          "watermark-guard host swaps", self.n_guard_swaps)
        c("scheduler_infeasible_shed_total",
          "rung-4 permanently-inadmissible cancellations",
          self.n_infeasible_shed)
        self.kvc.publish_metrics(registry, **labels)

    # ---------------------------------------------------------------- #
    def on_arrival(self, req: Request, t: float) -> None:
        req.set_state(State.QUEUED_PT, t)
        self.pt_queue.append(req)

    @property
    def running_gts(self) -> List[Request]:
        return [m for g in self.running_groups for m in g.members]

    def has_work(self) -> bool:
        return bool(self.pt_queue or self.gt_queue or self.running_groups)

    # ---------------------------------------------------------------- #
    # shared mechanics
    # ---------------------------------------------------------------- #
    def _admit_pt(self, req: Request, t: float, use_reserve: bool = True) -> bool:
        """Allocate prompt KVC (exact) for a PT about to run. A probe that
        does not fit is a batching decision, not a runtime allocation
        failure (those are what Table 1 counts)."""
        need = req.prompt_len - self.kvc.allocated_tokens(req.rid)
        if need <= 0:
            return True
        if self.kvc.can_allocate(need):
            return self.kvc.allocate(req.rid, need)
        if use_reserve and self.kvc.allocate_reserve(
                req.rid, blocks_for(need, self.cfg.block_size)):
            return True
        return False

    def _grant_pt_capacity(self, req: Request, want: int,
                           allow_general: bool) -> int:
        """Allocate capacity for up to `want` more prompt tokens, block-
        granular, reserve first (the reserve exists to admit PTs, §3.3);
        the general pool is touched only when no GT is waiting for it —
        that is the resource-responsibility decoupling. Chunked prompts
        hold KVC only for processed chunks (§2.4 / fig 6)."""
        slack = self.kvc.allocated_tokens(req.rid) - req.prompt_done
        if slack >= want:
            return want
        need_blocks = blocks_for(want - slack, self.cfg.block_size)
        from_res = min(need_blocks, self.kvc.free_reserve)
        if from_res > 0:
            self.kvc.allocate_reserve(req.rid, from_res)
        if allow_general:
            from_gen = min(need_blocks - from_res, self.kvc.free_general)
            if from_gen > 0:
                self.kvc.extend(req.rid, from_gen)
        return min(want,
                   self.kvc.allocated_tokens(req.rid) - req.prompt_done)

    def _schedule_gt_member(self, req: Request, t: float) -> bool:
        """Exact-allocate the remaining padded RL for a GT (plus restoring
        prompt+generated KV space if it was swapped out)."""
        total = req.prompt_len + req.generated + req.remaining_predicted
        need = total - self.kvc.allocated_tokens(req.rid)
        if need > 0:
            # a GT with no live allocation (swapped out, or migrated in
            # from a peer instance) is a *new* concurrent request — the
            # same cap _fill_pts enforces bounds it, or an engine would
            # be asked for more slots than it has
            if req.rid not in self.kvc.allocs \
                    and len(self.kvc.allocs) >= self.cfg.max_batch_reqs:
                return False
            if not self.kvc.can_allocate(need):
                return False
            self.kvc.allocate(req.rid, need)
        # recycle the PT-admission reserve (§3.3: reserve is for adding PTs)
        self.kvc.release_reserve(req.rid)
        req.alloc_rl = req.generated + req.remaining_predicted
        self.kvc.set_used(req.rid, req.prompt_len + req.generated)
        req._run_start = req.generated
        req.set_state(State.RUNNING_GT, t)
        return True

    def _complete(self, req: Request, t: float) -> None:
        req.set_state(State.COMPLETED, t)
        req.t_complete = t
        self.kvc.free(req.rid)
        self.kvc.swap_release(req.rid)     # defensive: no image outlives it
        self.completed.append(req)

    def notify_eos(self, req: Request, at_generated: int) -> None:
        """The engine observed EOS at response token ``at_generated``
        (1-based count). Clamps the ground-truth RL so ``finish_iteration``
        completes the request. Tolerant of *lagged* delivery (an async
        engine may drain sampled tokens iterations after they were
        produced): clamping at or below tokens already accounted simply
        completes the request at the next ``finish_iteration`` — the
        completion check is ``generated >= true_rl``, not equality."""
        req.true_rl = min(req.true_rl, max(1, at_generated))

    def decode_horizon(self, plan: IterationPlan, max_k: int) -> int:
        """How many consecutive iterations (including the one just planned)
        are guaranteed to keep the decode-batch membership fixed — no
        admission, KVC allocation, under-provision, preemption, or
        pipelining event can fire before the horizon's last
        ``finish_iteration``. EOS-driven completions *inside* the horizon
        only ever shrink the batch when the queues are empty; under memory
        pressure (non-empty queues certified KVC-blocked by
        ``_admission_horizon``) an EOS completion frees KVC that could
        admit a waiter, so an engine fusing a pressure window must
        truncate it at the first EOS (``ServingEngine`` does — the device
        while_loop early-exits and the host replays only the iterations
        that ran).

        This is what lets an engine fuse K decode iterations into one
        device dispatch while the per-iteration scheduler replay stays
        bitwise-identical: events are provably absent from the window, so
        each replayed ``form_batch`` returns the same membership. The
        horizon may only ever *underestimate* (a shorter window is always
        correct, just slower).
        """
        if max_k <= 1 or plan.prompt_items or not plan.decode_reqs:
            return 1
        k = max_k
        if self.pt_queue or self.gt_queue:
            # non-empty queues: fuse only as far as the KVC-bound
            # no-admission certificate reaches (policies without one
            # certify nothing and fall back to per-iteration dispatch)
            k = min(k, self._admission_horizon(max_k))
        pipe = getattr(self, "pipe", None)
        if pipe is not None and pipe.active:
            # hosted-slot deadlines preempt at a *known* owner age — fuse
            # up to (not past) the earliest expiry
            k = min(k, self._pipe_expiry_horizon(pipe, max_k))
        if k <= 1:
            return 1
        for r in plan.decode_reqs:
            # completion at true_rl (EOS may land earlier: handled by the
            # replay); under-provision (rescue/preempt) at alloc_rl
            k = min(k, max(1, r.true_rl - r.generated),
                    max(1, r.alloc_rl - r.generated))
        return k

    def _admission_horizon(self, max_k: int) -> int:
        """Iterations (starting with the one just planned) during which
        provably nothing in the waiting queues can be admitted, assuming
        no completion / under-provision / pipelining event fires earlier
        (``decode_horizon`` bounds those separately). Base policies have
        no certificate: 1 (this iteration already admitted nothing)."""
        return 1

    def _pipe_expiry_horizon(self, pipe, max_k: int) -> int:
        """Iterations until the earliest hosted-slot deadline can fire.
        Base policies are conservative: 1 (the old always-bail rule)."""
        return 1

    def cancel(self, rid: int, t: float) -> Optional[Request]:
        """Remove a request from every scheduler structure — waiting
        queues, running groups — and free its KVC. Returns the detached
        ``Request`` (state ``ABORTED``), or None when the rid is unknown
        or already completed. This is the hook the engine's ``abort`` and
        the cluster's crash recovery lean on; policies with extra
        bookkeeping (KVC pipelining) override and extend it."""
        req = None
        for q in (self.pt_queue, self.gt_queue):
            for r in list(q):
                if r.rid == rid:
                    q.remove(r)
                    req = r
                    break
            if req is not None:
                break
        if req is None:
            for grp in self.running_groups:
                for m in grp.members:
                    if m.rid == rid:
                        grp.members.remove(m)
                        req = m
                        break
                if req is not None:
                    break
            if req is not None and any(not g.members
                                       for g in self.running_groups):
                self.running_groups = [g for g in self.running_groups
                                       if g.members]
                self.group_completed = True    # mirror finish_iteration
        if req is None:
            return None
        self.swap_hold.pop(rid, None)
        self.kvc.free(rid)
        self.kvc.swap_release(rid)         # drop any host-offloaded image
        req.set_state(State.ABORTED, t)
        return req

    def _pt_finished(self, req: Request, t: float) -> None:
        """Prompt fully processed → request becomes a queued GT. The PT
        iteration itself produces the first response token (§1)."""
        req.prompt_done = req.prompt_len
        if req.generated == 0:
            req.generated = 1
        req.occupied_kvc = req.prompt_len + req.generated
        self.kvc.set_used(req.rid, req.occupied_kvc)
        if req.t_first_token is None:
            req.t_first_token = t
        if req.done:
            self._complete(req, t)
            return
        req.set_state(State.QUEUED_GT, t)
        self.enqueue_gt(req)

    # ---------------------------------------------------------------- #
    # GT-queue chokepoint + incremental min-demand accounting
    # ---------------------------------------------------------------- #
    def _gt_need_blocks(self, r: Request) -> int:
        """Exact-allocation demand of a queued GT, in blocks — the quantity
        ``_schedule_gt_member`` tests against ``free_general``."""
        need = (r.prompt_len + r.generated + r.remaining_predicted) \
            - self.kvc.allocated_tokens(r.rid)
        return blocks_for(need, self.cfg.block_size)

    def enqueue_gt(self, req: Request) -> None:
        """Every GT enqueue goes through here so the min-demand heaps stay
        consistent with the queue. Policies without a KVC certificate skip
        the bookkeeping (``_track_gt_demand`` False)."""
        self.gt_queue.append(req)
        if self._track_gt_demand:
            self._push_gt_demand(req)

    def _push_gt_demand(self, req: Request) -> None:
        nb = self._gt_need_blocks(req)
        heapq.heappush(self._gt_need_heap, (nb, req.rid))
        if req.rid in self.kvc.allocs:
            heapq.heappush(self._gt_need_res_heap, (nb, req.rid))
        heapq.heappush(self._gt_host_heap,
                       (max(1, req.remaining_predicted), req.rid))

    def _heap_min(self, heap: List[Tuple[int, int]], value_fn,
                  resident_only: bool = False) -> Optional[int]:
        """Smallest current value over queued (non-held) GTs. Lazy: dead
        entries are popped, re-keyed entries re-pushed — each discard or
        re-key is paid for by the queue/demand event that caused it, so
        the certificate query is O(1) amortized instead of a queue scan."""
        while heap:
            val, rid = heap[0]
            r = self.gt_queue.get(rid)
            if r is None or rid in self.swap_hold \
                    or (resident_only and rid not in self.kvc.allocs):
                heapq.heappop(heap)
                continue
            cur = value_fn(r)
            if cur != val:
                heapq.heapreplace(heap, (cur, rid))
                continue
            return val
        return None

    def release_swap_holds(self) -> None:
        """Guard pressure released: held GTs rejoin the admission path
        (their swap-in leg is charged when the engine actually restores
        them). Re-pushes demand entries for still-queued holds — queries
        discarded their heap entries while held."""
        if self._track_gt_demand:
            for rid, req in self.swap_hold.items():
                if self.gt_queue.get(rid) is not None:
                    self._push_gt_demand(req)
        self.swap_hold.clear()

    # ---------------------------------------------------------------- #
    # to be provided by policies
    # ---------------------------------------------------------------- #
    def form_batch(self, t: float) -> IterationPlan:
        raise NotImplementedError

    def finish_iteration(self, t: float) -> None:
        raise NotImplementedError


# ------------------------------------------------------------------------- #
class EconoServeScheduler(BaseScheduler):
    """The full system; feature flags reproduce -D / -SD / -SDO."""
    def __init__(self, cfg: SchedulerConfig, cost: CostModel,
                 name: str = "econoserve"):
        super().__init__(cfg, cost)
        self.name = name
        self.pipe = PipeBook(buffer_tokens=0, min_size=cfg.block_size)
        self.zombies: Dict[int, List[Request]] = {}   # host rid -> children
        self.host_of: Dict[int, Request] = {}
        if cfg.ordering and cfg.incremental_queues:
            self.pt_queue = OrderedQueue(is_gt=False, index=cfg.queue_index)
            self.gt_queue = OrderedQueue(is_gt=True, index=cfg.queue_index)
            self._track_gt_demand = True

    @staticmethod
    def _age_of(req: Request) -> int:
        """Tokens the request has grown into its current allocation span."""
        return req.generated - getattr(req, "_run_start", 0)

    # -------------------------------------------------------------- #
    def _buffer_tokens(self, rl: int) -> int:
        return max(self.cfg.block_size,
                   int(math.ceil(rl * self.cfg.buffer_frac)))

    # -------------------------------------------------------------- #
    # pressure-proof megastep certificates (decode_horizon hooks)
    # -------------------------------------------------------------- #
    def _admission_horizon(self, max_k: int) -> int:
        """Conservative KVC-bound certificate: during a pure-decode window
        the KVC counters are frozen (exact allocation — ``used`` grows,
        ``allocated`` does not, and the caller excludes completion /
        under-provision / pipelining events from the window), so any
        admission blocker that is *independent of queue ordering* extends
        from "blocked now" to "blocked for the whole window". O(1) counter
        reads except the two explicitly-noted queue scans, which run once
        per window (not per iteration).

        Ordering-dependent outcomes (deadline buckets roll with t, so a
        different head may be picked at a later iteration) can never be
        certified — whenever free KVC could fund *any* pick we bail to 1.
        """
        kvc = self.kvc
        if self.pt_queue:
            # _fill_pts admits iff budget >= 1 AND kvc_avail >= 1 AND the
            # picked head is either resident (mid-chunk, exempt from the
            # concurrency cap) or under the cap. budget and residency are
            # frozen during the window; kvc_avail = reserve + (general
            # when no GT waits) is frozen too.
            budget = self.cfg.tfs - len(self.running_gts)
            if budget >= 1:
                fundable = kvc.free_reserve > 0 or (
                    not self.gt_queue and kvc.free_general > 0)
                if fundable:
                    if len(kvc.allocs) < self.cfg.max_batch_reqs:
                        return 1
                    # cap reached: only a resident (KVC-holding) PT can be
                    # granted; the pick is ordering-dependent, so any
                    # resident waiter voids the certificate (queue scan)
                    if any(kvc.allocated_tokens(r.rid) > 0
                           for r in self.pt_queue):
                        return 1
        if self.gt_queue:
            # _fill_gts admits a queued GT iff its exact-allocation demand
            # (prompt + generated + remaining_predicted - already-held,
            # all frozen while the GT waits) fits the general pool, and —
            # for GTs holding no allocation (swapped/migrated) — the
            # concurrency cap has room. Ordering only changes *which*
            # admissible candidate goes first, so "no candidate is
            # admissible" is t-independent and certifies the window
            # (queue scan, once per window)
            if kvc.free_general > 0:
                cap_full = len(kvc.allocs) >= self.cfg.max_batch_reqs
                if self._track_gt_demand:
                    # incremental min-demand counter: the cheapest queued
                    # demand is a heap peek (amortized O(1)), so the
                    # partially-free regime certifies without a queue scan
                    m = self._heap_min(
                        self._gt_need_res_heap if cap_full
                        else self._gt_need_heap,
                        self._gt_need_blocks, resident_only=cap_full)
                    if m is not None and m <= kvc.free_general:
                        return 1
                else:
                    for r in self.gt_queue:
                        if r.rid in self.swap_hold:
                            continue  # guard-held: fills skip it too
                        if cap_full and r.rid not in kvc.allocs:
                            continue  # _schedule_gt_member's cap rejects it
                        need = (r.prompt_len + r.generated
                                + r.remaining_predicted) \
                            - kvc.allocated_tokens(r.rid)
                        if blocks_for(need, self.cfg.block_size) \
                                <= kvc.free_general:
                            return 1
            if self.cfg.pipelining and self.pipe.open_slots:
                # hosted placement: open-slot capacity *shrinks* as owners
                # age (1 token/iteration) while queued demand is frozen,
                # so "cheapest demand exceeds the largest slot now"
                # certifies the whole window
                cap = self.pipe.max_hostable(self._age_of)
                if cap >= 1:
                    if self._track_gt_demand:
                        m = self._heap_min(
                            self._gt_host_heap,
                            lambda r: max(1, r.remaining_predicted))
                        if m is not None and m <= cap:
                            return 1
                    elif any(max(1, r.remaining_predicted) <= cap
                             for r in self.gt_queue
                             if r.rid not in self.swap_hold):
                        return 1
        return max_k

    def _pipe_expiry_horizon(self, pipe, max_k: int) -> int:
        """A hosted slot expires at the ``finish_iteration`` where its
        owner's run age reaches ``deadline_age`` — deterministic, so the
        window may extend through (not past) the earliest expiry.
        Completed (zombie) owners stop aging and never expire."""
        k = max_k
        for s in pipe.active:
            if s.child is None or s.owner.state != State.RUNNING_GT:
                continue
            k = min(k, max(1, s.deadline_age - self._age_of(s.owner)))
        return k

    def cancel(self, rid: int, t: float) -> Optional[Request]:
        """Cancel with KVC-pipelining bookkeeping: vacate the lent slot a
        hosted victim occupied, preempt children hosted inside the
        victim's span (their memory disappears with it), and release the
        host's zombie allocation when the victim was its last child."""
        req = super().cancel(rid, t)
        if req is None:
            return None
        self.pipe.release_child(req)
        host = self.host_of.pop(rid, None)
        orphans = self.pipe.drop_owner(req)
        for o in orphans:
            for g in self.running_groups:
                if o in g.members:
                    g.members.remove(o)
            self._preempt(o, t, offload_free=False)
        self.running_groups = [g for g in self.running_groups if g.members]
        if host is not None:
            self._maybe_free_zombie(host)
        return req

    def _sorted_gt_queue(self, t: float) -> List[Request]:
        if self.cfg.ordering:
            if isinstance(self.gt_queue, OrderedQueue):
                return self.gt_queue.sorted_view(t)
            return sort_queue(self.gt_queue, t, is_gt=True)
        return sorted(self.gt_queue, key=lambda r: r.arrival)

    def _sorted_pt_queue(self, t: float) -> List[Request]:
        if self.cfg.ordering:
            if isinstance(self.pt_queue, OrderedQueue):
                return self.pt_queue.sorted_view(t)
            return sort_queue(self.pt_queue, t, is_gt=False)
        return sorted(self.pt_queue, key=lambda r: r.arrival)

    # -------------------------------------------------------------- #
    def _fill_gts(self, t: float) -> int:
        """①: select GT groups (or single GTs) until KVC fully allocated."""
        n_sel = 0
        q = [r for r in self._sorted_gt_queue(t)
             if r.rid not in self.swap_hold]
        # remaining_predicted is constant within one _fill_gts call (it only
        # moves in finish_iteration), so the RL bucket of each candidate is
        # computed at most once per call instead of O(queue) per group
        buckets: Dict[int, int] = {}

        def rl_bucket(r: Request) -> int:
            b = buckets.get(r.rid)
            if b is None:
                b = bucketize(max(1, r.remaining_predicted), self.cfg.bucket)
                buckets[r.rid] = b
            return b

        while q:
            free_tok = self.kvc.free_tokens()
            if free_tok < self.cfg.block_size:
                break
            i = pick_fit(q, free_tok, t, is_gt=True) \
                if self.cfg.ordering else 0
            if i is None:
                i = 0
            head = q[i]
            if head.remaining_predicted > free_tok and not self.cfg.sync_groups:
                break
            if self.cfg.sync_groups:
                key = rl_bucket(head)
                same = [r for r in q if rl_bucket(r) == key]
                grp = Group(key=key)
                for r in same:
                    if r.remaining_predicted > self.kvc.free_tokens():
                        continue            # split the group to fit (§3.3.1)
                    if self._schedule_gt_member(r, t):
                        grp.members.append(r)
                        self.gt_queue.remove(r)
                        q.remove(r)
                        n_sel += 1
                        if self.cfg.pipelining:
                            self.pipe.buffer_tokens = self._buffer_tokens(key)
                            self.pipe.offer(r, r.remaining_predicted)
                if grp.members:
                    self.running_groups.append(grp)
                else:
                    break
            else:
                r = head
                if r.remaining_predicted > free_tok:
                    break
                if self._schedule_gt_member(r, t):
                    self.running_groups.append(Group(
                        key=bucketize(max(1, r.remaining_predicted),
                                      self.cfg.bucket), members=[r]))
                    self.gt_queue.remove(r)
                    q.remove(r)
                    n_sel += 1
                else:
                    break
        return n_sel

    def _fill_hosted(self, t: float) -> int:
        """②: KVC pipelining — place queued GTs into lent slots."""
        if not self.cfg.pipelining:
            return 0
        n_sel = 0
        q = [r for r in self._sorted_gt_queue(t)
             if r.rid not in self.swap_hold]
        while q and self.pipe.open_slots:
            cap = self.pipe.max_hostable(self._age_of)
            if cap < 1:
                break
            i = pick_fit(q, cap, t, is_gt=True)
            if i is None:
                break
            r = q[i]
            if r.rid not in self.kvc.allocs \
                    and len(self.kvc.allocs) >= self.cfg.max_batch_reqs:
                break                        # engine concurrency cap
            need = max(1, r.remaining_predicted)
            slot = self.pipe.place(r, need, self._age_of)
            if slot is None:
                break
            # hosted GTs draw no new KVC; register usage under their rid
            self.kvc.allocs.setdefault(r.rid, Allocation())
            self.kvc.allocs[r.rid].lent_tokens = need
            self.kvc.release_reserve(r.rid)   # left the PT phase
            r.alloc_rl = r.generated + need
            r._run_start = r.generated
            r.set_state(State.RUNNING_GT, t)
            self.host_of[r.rid] = slot.owner
            self.running_groups.append(Group(key=bucketize(need,
                                                           self.cfg.bucket),
                                             members=[r]))
            self.gt_queue.remove(r)
            q.remove(r)
            n_sel += 1
            self.n_hosted += 1
        return n_sel

    def _fill_pts(self, t: float) -> List[Tuple[Request, int]]:
        """③: add PTs (chunked if needed) until TFS is reached. KVC for a
        chunked prompt is allocated chunk-by-chunk; a prompt that cannot get
        capacity right now is skipped, not allowed to block the queue."""
        items: List[Tuple[Request, int]] = []
        budget = self.cfg.tfs - len(self.running_gts)
        allow_general = not self.gt_queue     # GTs own the general pool
        q = self._sorted_pt_queue(t)
        while q and budget >= 1:
            kvc_avail = self.kvc.free_reserve * self.cfg.block_size \
                + (self.kvc.free_tokens() if allow_general else 0)
            if kvc_avail < 1:
                break
            limit = min(budget, kvc_avail)
            i = pick_fit(q, limit, t, is_gt=False) \
                if self.cfg.ordering else 0
            if i is None:
                i = 0                        # no perfect fit → chunk the head
            r = q[i]
            # the concurrency cap bounds *new* admissions only: a chunked
            # prompt mid-flight already holds KVC (and an engine slot), so
            # continuing it adds no concurrent request — without this
            # exemption a full batch starves every in-flight chunked PT
            # until something completes. len(allocs) alone is the live
            # concurrency count: every grant (including ones made earlier
            # in this very loop) creates its alloc entry immediately.
            resident = self.kvc.allocated_tokens(r.rid) > 0
            if (not resident
                    and len(self.kvc.allocs) >= self.cfg.max_batch_reqs):
                break                        # engine concurrency cap
            remaining = r.prompt_len - r.prompt_done
            chunk = self._grant_pt_capacity(r, min(remaining, budget),
                                            allow_general)
            q.remove(r)
            if chunk <= 0:
                continue                     # cannot serve now; try others
            r.set_state(State.RUNNING_PT, t)
            if r.t_start_exec is None:
                r.t_start_exec = t
            items.append((r, chunk))
            self.pt_queue.remove(r)
            budget -= chunk
        return items

    # -------------------------------------------------------------- #
    def _evict_waiting(self, t: float, need_tokens: int) -> bool:
        """Deadlock relief: when nothing runs and nothing fits, swap out the
        lowest-priority *waiting* GTs' KV until `need_tokens` are free."""
        victims = list(reversed(self._sorted_gt_queue(t)))
        freed = False
        for v in victims:
            if self.kvc.free_tokens() >= need_tokens:
                break
            if self.kvc.allocated_tokens(v.rid) == 0:
                continue
            tokens = v.prompt_len + v.generated
            self.kvc.free(v.rid)
            self.pending_extra_time += 2 * self.cost.swap_time(tokens)
            v.swap_time += 2 * self.cost.swap_time(tokens)
            v.occupied_kvc = tokens        # held in host memory now
            v.prompt_done = v.prompt_len
            self.n_preempt_swap += 1
            freed = True
        return freed

    def fits_ever(self, tokens: int) -> bool:
        """Frozen-demand feasibility: would ``tokens`` of exact-alloc
        demand fit this scheduler's *empty* post-shrink cache? The rung-4
        shed uses the negation locally; the fleet's shed-retry tier asks
        it of every live peer to decide between a router-level re-route
        (someone can fund the demand) and a terminal shed (no one ever
        will)."""
        return blocks_for(tokens, self.cfg.block_size) \
            <= self.kvc.total_blocks - self.kvc.pending_shrink

    def _shed_infeasible(self, t: float) -> int:
        """Pressure-ladder rung 4: after a capacity squeeze, a queued
        request whose frozen admission demand exceeds what even an
        *empty* post-shrink cache can offer will never be admitted again
        — demand is frozen while it waits and capacity only shrinks.
        Called from form_batch's deadlock relief (nothing runs, nothing
        placeable, every softer rung exhausted): cancel the doomed
        requests and park them in ``infeasible_shed`` for the backend —
        which either surfaces them as terminal sheds or hands them back
        to the fleet's shed-retry tier for a re-route to a peer that can
        still fit them. Returns how many were cancelled."""
        doomed = [r for r in list(self.gt_queue)
                  if not self.fits_ever(r.prompt_len + r.generated
                                        + r.remaining_predicted)]
        doomed += [r for r in list(self.pt_queue)
                   if not self.fits_ever(r.prompt_len
                                         + max(r.padded_rl, 1))]
        for r in doomed:
            self.cancel(r.rid, t)
            self.infeasible_shed.append(r)
            self.n_infeasible_shed += 1
        return len(doomed)

    # -------------------------------------------------------------- #
    # watermark-guard backpressure (proactive host swap, rung 2)
    # -------------------------------------------------------------- #
    def swap_victims(self, max_n: Optional[int] = None) -> List[Request]:
        """Waiting GTs eligible for proactive swap-out, most-KVC-first —
        each victim releases the most device pressure (rid tie-break
        keeps victim choice deterministic)."""
        cands = [r for r in self.gt_queue
                 if r.rid not in self.swap_hold
                 and self.kvc.allocated_tokens(r.rid) > 0]
        cands.sort(key=lambda r: (-self.kvc.allocated_tokens(r.rid), r.rid))
        return cands if max_n is None else cands[:max_n]

    def guard_swap_out(self, req: Request, t: float) -> int:
        """Proactively swap a waiting GT's device KVC out (the engine
        captures the page image at its next slot sweep) and hold it out
        of admission until the guard releases pressure. Charges only the
        out leg — the in leg is charged at restore. Returns the token
        extent moved to host."""
        tokens = req.prompt_len + req.generated
        self.kvc.free(req.rid)
        out_t = self.cost.swap_out_time(tokens)
        self.pending_extra_time += out_t
        req.swap_time += out_t
        req.occupied_kvc = tokens          # held in host memory now
        req.prompt_done = req.prompt_len
        self.swap_hold[req.rid] = req
        self.n_guard_swaps += 1
        return tokens

    def form_batch(self, t: float) -> IterationPlan:
        plan = IterationPlan()
        n_gt_sel = 0
        # GT-side fill: Algorithm 1 gates this on group completion; we also
        # run it whenever queued GTs could be placed (free KVC or open lent
        # slots) — same policy, lower GT queuing delay (see DESIGN.md).
        if (self.group_completed or not self.running_groups
                or (self.gt_queue and
                    (self.kvc.free_tokens() >= self.cfg.block_size
                     or self.pipe.open_slots))):
            n_gt_sel += self._fill_gts(t)
            n_gt_sel += self._fill_hosted(t)
            self.group_completed = False
        if not self.running_groups and n_gt_sel == 0 and self.gt_queue:
            # liveness trumps backpressure: before deadlock relief, give
            # guard-held requests back to the admission path
            if self.swap_hold:
                self.release_swap_holds()
                n_gt_sel += self._fill_gts(t)
                n_gt_sel += self._fill_hosted(t)
        if not self.running_groups and n_gt_sel == 0 and self.gt_queue:
            head = self._sorted_gt_queue(t)[0]
            need = head.prompt_len + head.generated + head.remaining_predicted
            if self._evict_waiting(t, need):
                n_gt_sel += self._fill_gts(t)
                n_gt_sel += self._fill_hosted(t)
        if (not self.running_groups and n_gt_sel == 0
                and self.kvc.n_shrinks
                and (self.gt_queue or self.pt_queue)):
            # every softer rung failed and capacity has shrunk: shed what
            # can never fit again, then retry with the blocks it released
            if self._shed_infeasible(t):
                n_gt_sel += self._fill_gts(t)
                n_gt_sel += self._fill_hosted(t)
        plan.prompt_items = self._fill_pts(t)
        plan.decode_reqs = self.running_gts
        n_q = len(self.pt_queue) + len(self.gt_queue)
        if self.cfg.sync_groups:
            plan.sched_time = self.cost.sched_time_grouped(
                n_q, n_gt_sel + len(plan.prompt_items))
        else:
            plan.sched_time = self.cost.sched_time_fcfs(
                n_q, n_gt_sel + len(plan.prompt_items)) * 4
        plan.extra_time = self.pending_extra_time
        self.pending_extra_time = 0.0
        self.current_plan = plan
        return plan

    # -------------------------------------------------------------- #
    def _preempt(self, req: Request, t: float, offload_free: bool) -> None:
        req.n_preemptions += 1
        self.pipe.release_child(req)
        orphans = self.pipe.drop_owner(req)
        for o in orphans:
            self._preempt(o, t, offload_free=False)   # children swap out
        host = self.host_of.pop(req.rid, None)
        if offload_free:
            # drop KV — requeue as a PT that recomputes prompt + generated
            self.n_preempt_free += 1
            self.kvc.free(req.rid)
            req.occupied_kvc = 0
            req.prompt_done = 0
            req.set_state(State.PREEMPTED, t)
            self.pt_queue.append(req)
        else:
            # offload: KV moves to host memory; pay swap now + swap-in later
            self.n_preempt_swap += 1
            tokens = req.prompt_len + req.generated
            self.pending_extra_time += 2 * self.cost.swap_time(tokens)
            req.swap_time += 2 * self.cost.swap_time(tokens)
            self.kvc.free(req.rid)
            # the KV lives in host memory; the request still "occupies" it
            # for ordering purposes (O5: release it earlier)
            req.occupied_kvc = tokens
            req.prompt_done = req.prompt_len
            req.set_state(State.PREEMPTED, t)
            # re-prediction of the remaining length (§3.3.2)
            req.padded_rl = req.generated + bucketize(
                max(1, req.padded_rl - req.generated) + self.cfg.bucket,
                self.cfg.bucket)
            self.enqueue_gt(req)
        if host is not None:
            self._maybe_free_zombie(host)

    def _try_reserve_rescue(self, req: Request) -> bool:
        """① on under-provision: extend from the reserved KVC (O4)."""
        if req.hosted:
            return False                 # lent space cannot be extended
        if not self.kvc.allocate_reserve(req.rid, 1):
            return False
        self.n_reserve_rescues += 1
        req.alloc_rl += self.cfg.block_size
        req.padded_rl = req.alloc_rl
        return True

    def _handle_underprovision(self, req: Request, t: float) -> None:
        """② no reserve left (or hosted): preempt (offload-free by default)."""
        if req.hosted or not self.cfg.offload_free:
            self._preempt(req, t, offload_free=False)
        else:
            self._preempt(req, t, offload_free=True)
        # requeued with a fresh remaining estimate (L_new, §3.3.2); the
        # offload-free path re-prefills, the swap path set L_new in _preempt
        if req.prompt_done == 0:
            req.padded_rl = req.generated + bucketize(
                self.cfg.bucket, self.cfg.bucket)

    def finish_iteration(self, t: float) -> None:
        plan = self.current_plan
        assert plan is not None
        n_completed = 0
        # ---- PTs -----------------------------------------------------
        for req, chunk in plan.prompt_items:
            req.prompt_done += chunk
            req.occupied_kvc = req.prompt_done + req.generated
            self.kvc.set_used(req.rid, req.occupied_kvc)
            if req.prompt_done >= req.prompt_len:
                self._pt_finished(req, t)
            else:
                req.set_state(State.QUEUED_PT, t)
                self.pt_queue.append(req)      # chunked prompt continues
        # ---- GTs -----------------------------------------------------
        for grp in list(self.running_groups):
            grp.age += 1
            for m in list(grp.members):
                m.generated += 1
                m.occupied_kvc = m.prompt_len + m.generated
                self.kvc.add_used(m.rid, 1)
                if m.t_first_token is None:
                    m.t_first_token = t
                if m.done:
                    grp.members.remove(m)
                    self._finish_member(m, t)
                    n_completed += 1
                elif m.generated >= m.alloc_rl:
                    self.n_underprov += 1
                    if not self._try_reserve_rescue(m):
                        grp.members.remove(m)
                        self._handle_underprovision(m, t)
            if not grp.members:
                self.running_groups.remove(grp)
                self.group_completed = True
        # ---- KVCPipe deadline enforcement -----------------------------
        expired = self.pipe.expired(self._age_of)
        for slot in expired:
            child = slot.child
            self.pipe.release_child(child)
            for g in self.running_groups:
                if child in g.members:
                    g.members.remove(child)
            self._preempt(child, t, offload_free=False)
        self.running_groups = [g for g in self.running_groups if g.members]
        self.iter_completion_counts.append(n_completed)

    def _finish_member(self, m: Request, t: float) -> None:
        """Completion honoring zombie (lent-space) semantics."""
        self.pipe.release_child(m)
        host = self.host_of.pop(m.rid, None)
        if host is not None:
            # hosted GT: its RL KV lived in the host's span (lent), but its
            # own prompt blocks are real — free them normally
            self._complete(m, t)
            self._maybe_free_zombie(host)
            return
        children = [s.child for s in self.pipe.active
                    if s.owner is m and s.child is not None]
        if children:
            # defer the free until hosted children vacate
            self.zombies[m.rid] = children
            m.set_state(State.COMPLETED, t)
            m.t_complete = t
            self.completed.append(m)
            self.pipe.open_slots = [s for s in self.pipe.open_slots
                                    if s.owner is not m]
        else:
            self.pipe.drop_owner(m)
            self._complete(m, t)

    def _maybe_free_zombie(self, host: Request) -> None:
        if host.rid in self.zombies:
            kids = [c for c in self.zombies[host.rid]
                    if c.state == State.RUNNING_GT]
            if not kids:
                del self.zombies[host.rid]
                self.kvc.free(host.rid)


def make_econoserve(cfg: SchedulerConfig, cost: CostModel,
                    variant: str = "full") -> EconoServeScheduler:
    """variant ∈ {'d', 'sd', 'sdo', 'full', 'oracle'} (ablation §4)."""
    import dataclasses
    flags = {
        "d": dict(sync_groups=False, ordering=False, pipelining=False),
        "sd": dict(sync_groups=True, ordering=False, pipelining=False),
        "sdo": dict(sync_groups=True, ordering=True, pipelining=False),
        "full": dict(sync_groups=True, ordering=True, pipelining=True),
        "oracle": dict(sync_groups=True, ordering=True, pipelining=True),
    }[variant]
    cfg = dataclasses.replace(cfg, **flags)
    names = {"d": "econoserve-d", "sd": "econoserve-sd",
             "sdo": "econoserve-sdo", "full": "econoserve",
             "oracle": "oracle"}
    return EconoServeScheduler(cfg, cost, name=names[variant])
