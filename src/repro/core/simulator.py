"""Discrete-event serving simulator (single engine).

Drives any BaseScheduler: deliver arrivals → form batch → advance the clock
by scheduling + iteration time → commit iteration effects → repeat.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from .costmodel import CostModel
from .metrics import IterSample, SimResult
from .request import Request
from .scheduler import BaseScheduler


def simulate(requests: Sequence[Request], scheduler: BaseScheduler,
             cost: CostModel, max_time: Optional[float] = None,
             max_iters: int = 2_000_000,
             collect_samples: bool = True) -> SimResult:
    """``collect_samples=False`` skips per-iteration IterSample records —
    for production-size traces where only aggregate results matter the
    sample list (and its per-iteration KVC snapshots) is pure overhead."""
    reqs = sorted(requests, key=lambda r: r.arrival)
    n = len(reqs)
    i_arr = 0
    t = 0.0
    samples: List[IterSample] = []
    iters = 0

    while iters < max_iters:
        # deliver due arrivals
        while i_arr < n and reqs[i_arr].arrival <= t + 1e-12:
            scheduler.on_arrival(reqs[i_arr], t)
            i_arr += 1
        plan = scheduler.form_batch(t)
        if plan.empty:
            if i_arr < n:
                t = max(t, reqs[i_arr].arrival)
                continue
            break                                    # drained
        ctxs = [r.prompt_len + r.generated for r in plan.decode_reqs]
        dt = cost.iteration_time(plan.prompt_tokens, ctxs)
        t_end = t + plan.sched_time + plan.extra_time + dt
        if max_time is not None and t_end > max_time:
            break
        for req, _ in plan.prompt_items:
            req.sched_time += plan.sched_time
        n_before = len(scheduler.completed)
        scheduler.finish_iteration(t_end)
        n_done = len(scheduler.completed) - n_before
        if collect_samples:
            samples.append(IterSample(
                t=t_end, dt=dt, forward_size=plan.forward_size,
                prompt_tokens=plan.prompt_tokens,
                n_decode=len(plan.decode_reqs),
                kvc_used_frac=scheduler.kvc.utilization,
                kvc_alloc_frac=scheduler.kvc.allocated_frac,
                sched_time=plan.sched_time, extra_time=plan.extra_time,
                n_completed=n_done))
        t = t_end
        iters += 1
        if i_arr >= n and not scheduler.has_work():
            break

    return SimResult(
        name=scheduler.name, requests=list(reqs), samples=samples,
        wall_time=t, tfs=scheduler.cfg.tfs,
        n_alloc_failures=scheduler.kvc.n_failures,
        n_allocs=scheduler.kvc.n_allocs,
        n_preempt_swap=getattr(scheduler, "n_preempt_swap", 0),
        n_preempt_free=getattr(scheduler, "n_preempt_free", 0),
        n_underprov=getattr(scheduler, "n_underprov", 0),
        n_reserve_rescues=getattr(scheduler, "n_reserve_rescues", 0))
