"""Discrete-event serving simulator.

``SimInstance`` models ONE engine instance as a steppable process: deliver
arrivals → form batch → advance the instance clock by scheduling +
iteration time → commit iteration effects. ``simulate`` drives a single
instance to completion (the original single-engine loop, unchanged in
behavior); ``repro.cluster.sim.ClusterSim`` interleaves N instances under a
shared event clock using the same primitive.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from .costmodel import CostModel
from .metrics import IterSample, SimResult
from .request import Request
from .scheduler import BaseScheduler


class SimInstance:
    """One serving instance as a discrete-event process.

    The instance owns its local clock ``t``: each committed ``step`` forms a
    batch at ``t`` and advances to the iteration's end time. Arrivals are
    pushed in via ``deliver`` (a queued request is visible to the next
    ``form_batch``); an idle instance's clock may be jumped forward by the
    caller before delivering (``advance_to``).
    """

    STEPPED = 1       # an iteration committed; clock advanced
    IDLE = 0          # empty plan: nothing schedulable at the current clock
    CUTOFF = -1       # the iteration would cross max_time; nothing committed

    def __init__(self, scheduler: BaseScheduler, cost: CostModel,
                 collect_samples: bool = True):
        self.scheduler = scheduler
        self.cost = cost
        self.collect_samples = collect_samples
        self.samples: List[IterSample] = []
        self.t = 0.0
        self.iters = 0

    # ------------------------------------------------------------------ #
    def deliver(self, req: Request, t: float) -> None:
        self.scheduler.on_arrival(req, t)

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def advance_to(self, t: float) -> None:
        """Jump an idle instance's clock forward (never backward)."""
        self.t = max(self.t, t)

    # ------------------------------------------------------------------ #
    def step(self, max_time: Optional[float] = None) -> int:
        """Run one iteration at the instance clock. Returns ``STEPPED``
        when an iteration committed (clock advanced to its end time),
        ``IDLE`` when the plan was empty, ``CUTOFF`` when the iteration
        would end past ``max_time`` (nothing committed)."""
        plan = self.scheduler.form_batch(self.t)
        if plan.empty:
            return self.IDLE
        ctxs = [r.prompt_len + r.generated for r in plan.decode_reqs]
        dt = self.cost.iteration_time(plan.prompt_tokens, ctxs)
        t_end = self.t + plan.sched_time + plan.extra_time + dt
        if max_time is not None and t_end > max_time:
            return self.CUTOFF
        for req, _ in plan.prompt_items:
            req.sched_time += plan.sched_time
        n_before = len(self.scheduler.completed)
        self.scheduler.finish_iteration(t_end)
        n_done = len(self.scheduler.completed) - n_before
        if self.collect_samples:
            self.samples.append(IterSample(
                t=t_end, dt=dt, forward_size=plan.forward_size,
                prompt_tokens=plan.prompt_tokens,
                n_decode=len(plan.decode_reqs),
                kvc_used_frac=self.scheduler.kvc.utilization,
                kvc_alloc_frac=self.scheduler.kvc.allocated_frac,
                sched_time=plan.sched_time, extra_time=plan.extra_time,
                n_completed=n_done))
        self.t = t_end
        self.iters += 1
        return self.STEPPED

    # ------------------------------------------------------------------ #
    def result(self, requests: Sequence[Request]) -> SimResult:
        sched = self.scheduler
        return SimResult(
            name=sched.name, requests=list(requests), samples=self.samples,
            wall_time=self.t, tfs=sched.cfg.tfs,
            n_alloc_failures=sched.kvc.n_failures,
            n_allocs=sched.kvc.n_allocs,
            n_preempt_swap=getattr(sched, "n_preempt_swap", 0),
            n_preempt_free=getattr(sched, "n_preempt_free", 0),
            n_underprov=getattr(sched, "n_underprov", 0),
            n_reserve_rescues=getattr(sched, "n_reserve_rescues", 0))


def simulate(requests: Sequence[Request], scheduler: BaseScheduler,
             cost: CostModel, max_time: Optional[float] = None,
             max_iters: int = 2_000_000,
             collect_samples: bool = True) -> SimResult:
    """``collect_samples=False`` skips per-iteration IterSample records —
    for production-size traces where only aggregate results matter the
    sample list (and its per-iteration KVC snapshots) is pure overhead."""
    reqs = sorted(requests, key=lambda r: r.arrival)
    n = len(reqs)
    i_arr = 0
    inst = SimInstance(scheduler, cost, collect_samples)

    while inst.iters < max_iters:
        # deliver due arrivals
        while i_arr < n and reqs[i_arr].arrival <= inst.t + 1e-12:
            inst.deliver(reqs[i_arr], inst.t)
            i_arr += 1
        status = inst.step(max_time)
        if status == SimInstance.IDLE:
            if i_arr < n:
                inst.advance_to(reqs[i_arr].arrival)
                continue
            break                                    # drained
        if status == SimInstance.CUTOFF:
            break
        if i_arr >= n and not scheduler.has_work():
            break

    return inst.result(reqs)
