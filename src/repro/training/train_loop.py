"""Training step + loop (pure JAX, remat inside the model's layer scans)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model
from repro.models.common import cross_entropy
from repro.models.config import ModelConfig

from .optimizer import AdamWConfig, apply_updates, init_state


def make_loss_fn(cfg: ModelConfig):
    F = cfg.frontend_tokens if cfg.frontend else 0

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        embeds = batch.get("embeds")
        logits, aux = model.forward_train(cfg, params, tokens, embeds)
        logits = logits[:, F:]                       # text positions only
        loss = cross_entropy(logits[:, :-1], tokens[:, 1:])
        if cfg.is_moe:
            loss = loss + cfg.aux_loss_coef * aux
        return loss, aux

    return loss_fn


def make_train_step(cfg: ModelConfig, opt: AdamWConfig) -> Callable:
    loss_fn = make_loss_fn(cfg)

    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt_state, gnorm = apply_updates(params, grads, opt_state,
                                                 opt)
        metrics = {"loss": loss, "aux": aux, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def train(cfg: ModelConfig, steps: int, *, opt: Optional[AdamWConfig] = None,
          batch_size: int = 8, seq_len: int = 128, seed: int = 0,
          log_every: int = 10, callback=None):
    """Single-host training loop used by examples/tests."""
    from .data import DataConfig, SyntheticDataset

    opt = opt or AdamWConfig()
    key = jax.random.PRNGKey(seed)
    params = model.init(cfg, key)
    opt_state = init_state(params, opt)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      batch_size=batch_size, seed=seed,
                      frontend_tokens=cfg.frontend_tokens if cfg.frontend else 0,
                      d_model=cfg.d_model)
    ds = SyntheticDataset(dcfg)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
    history = []
    for i, batch in enumerate(ds.batches()):
        if i >= steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if "embeds" in batch:
            batch["embeds"] = batch["embeds"].astype(cfg.dtype)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": i, **m})
            if callback:
                callback(i, m)
    return params, opt_state, history
