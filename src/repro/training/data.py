"""Synthetic token data pipeline (deterministic, seedable, sharded-friendly).

A Zipf-ish unigram stream with short-range structure — enough signal for
"loss decreases" integration tests and throughput benchmarking without any
external dataset.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    frontend_tokens: int = 0     # VLM/audio: embeddings supplied separately
    d_model: int = 0


class SyntheticDataset:
    """Markov-flavored token stream: next token depends on the previous one
    through a fixed random permutation with noise — learnable structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        self.perm = rng.permutation(v)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks ** 1.1)
        self.unigram /= self.unigram.sum()

    def batches(self, seed: Optional[int] = None) -> Iterator[Dict]:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed if seed is None else seed)
        while True:
            B, S = cfg.batch_size, cfg.seq_len
            toks = np.empty((B, S), np.int32)
            toks[:, 0] = rng.choice(cfg.vocab_size, size=B, p=self.unigram)
            noise = rng.random((B, S))
            rand = rng.choice(cfg.vocab_size, size=(B, S), p=self.unigram)
            for t in range(1, S):
                follow = self.perm[toks[:, t - 1]]
                toks[:, t] = np.where(noise[:, t] < 0.75, follow,
                                      rand[:, t])
            out = {"tokens": toks}
            if cfg.frontend_tokens:
                out["embeds"] = rng.standard_normal(
                    (B, cfg.frontend_tokens, cfg.d_model)).astype(np.float32) * 0.02
            yield out
