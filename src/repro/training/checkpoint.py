"""Checkpointing: flat param/opt-state dicts → msgpack + raw numpy buffers."""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


# parameter names themselves contain "/", so nested-dict paths are joined
# with the ASCII unit separator instead
_SEP = "\x1f"


def _pack(tree: Dict[str, Any]) -> bytes:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}{_SEP}{k}" if prefix else k, v)
        else:
            arr = np.asarray(node)
            flat[prefix] = {
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "data": arr.tobytes(),
            }

    walk("", tree)
    return msgpack.packb(flat, use_bin_type=True)


def _unpack(blob: bytes) -> Dict[str, Any]:
    flat = msgpack.unpackb(blob, raw=False)
    tree: Dict[str, Any] = {}
    for path, rec in flat.items():
        arr = np.frombuffer(rec["data"],
                            dtype=np.dtype(rec["dtype"])).reshape(rec["shape"])
        node = tree
        parts = path.split(_SEP)
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(arr)
    return tree


def save(path: str, params: Dict[str, Any],
         opt_state: Dict[str, Any] | None = None,
         meta: Dict[str, Any] | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {"params": params}
    if opt_state is not None:
        payload["opt_state"] = opt_state
    if meta is not None:
        payload["__meta__"] = {k: np.asarray(v) for k, v in meta.items()}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(_pack(payload))
    os.replace(tmp, path)


def load(path: str) -> Dict[str, Any]:
    with open(path, "rb") as f:
        return _unpack(f.read())
