"""AdamW in pure JAX with configurable state dtype.

bf16 moments (``state_dtype='bfloat16'``) are what lets arctic-480b train on
a single 256-chip v5e pod (see DESIGN.md §5); fp32 is the default for the
smaller models.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jax.Array]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100


def init_state(params: Params, cfg: AdamWConfig) -> Dict[str, Params]:
    dt = jnp.dtype(cfg.state_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    return cfg.lr * warm


def apply_updates(params: Params, grads: Params, state: Dict,
                  cfg: AdamWConfig) -> Tuple[Params, Dict, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    step = state["step"] + 1
    # global-norm clip in fp32
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.float32(1.0)
    lr = _schedule(cfg, step)
    dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * jnp.square(g)
        mh = m32 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v32 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p = params
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        new_p[k], new_m[k], new_v[k] = upd(params[k], grads[k],
                                           state["m"][k], state["v"][k])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
