"""Logical-axis → mesh-axis sharding rules (MaxText-style).

Parameters carry logical axis names (repro.models.common); this module maps
them to PartitionSpecs for a given mesh:

  * exactly one "model"-class logical axis per tensor is sharded over the
    mesh "model" axis (priority: experts > vocab > heads/kv > mlp > inner);
  * the d_model ("embed") axis is FSDP-sharded over "data" within a pod;
  * the "pod" axis (multi-pod mesh) is pure data parallelism: parameters
    replicated across pods, batch sharded over ("pod", "data").

Head counts not divisible by the model-axis size (56 heads, kv=8 on a
16-way axis) rely on GSPMD padding — the model body uses jit/GSPMD, not
shard_map, exactly for this.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import common as C
from repro.models import model as model_lib
from repro.models.config import ModelConfig

# logical axes that map to the tensor-parallel "model" mesh axis, in
# priority order (first match wins per tensor)
MODEL_CLASS = (C.EXPERT, C.VOCAB, C.HEADS, C.KV, C.MLP, C.INNER)


def spec_for_axes(axes: Tuple[Optional[str], ...], *,
                  fsdp: bool = True) -> P:
    out = []
    model_used = False
    data_used = False
    # find the highest-priority model-class axis present
    present = [a for a in axes if a in MODEL_CLASS]
    chosen = None
    for cls in MODEL_CLASS:
        if cls in present:
            chosen = cls
            break
    for a in axes:
        if a == chosen and not model_used:
            out.append("model")
            model_used = True
        elif a == C.EMBED and fsdp and not data_used:
            out.append("data")
            data_used = True
        else:
            out.append(None)
    return P(*out)


def param_specs(cfg: ModelConfig, mesh: Optional[Mesh] = None, *,
                fsdp: bool = True) -> Dict[str, P]:
    """Size-aware: any sharded dim that does not divide its mesh axis is
    demoted to replicated (explicit input shardings must divide evenly)."""
    tree = model_lib.param_tree(cfg)
    out = {}
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh \
        else {}
    for k, m in tree.items():
        spec = list(spec_for_axes(m.axes, fsdp=fsdp))
        if mesh is not None:
            for i, a in enumerate(spec):
                if a is not None and m.shape[i] % axis_size[a] != 0:
                    spec[i] = None
        out[k] = P(*spec)
    return out


def batch_axes(mesh: Mesh):
    """The data-parallel submesh axes for the batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def shard_params_abstract(cfg: ModelConfig, mesh: Mesh, *,
                          fsdp: bool = True) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract params with NamedShardings attached (for .lower())."""
    import jax.numpy as jnp
    tree = model_lib.param_tree(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    specs = param_specs(cfg, mesh, fsdp=fsdp)
    return {k: jax.ShapeDtypeStruct(m.shape, dt,
                                    sharding=NamedSharding(mesh, specs[k]))
            for k, m in tree.items()}


def cache_specs(cfg: ModelConfig, mesh: Mesh, *, batch: int, capacity: int,
                shard_batch: bool, shard_seq: bool) -> dict:
    """PartitionSpec tree matching model.init_cache structure.

    shard_batch: batch dim over ("pod","data") (decode_32k);
    shard_seq: context dim over "data" instead (long_500k, batch=1).
    Explicit input shardings must divide evenly, so every rule falls back
    (kv-heads → head_dim → replicated) based on the actual dim sizes.
    """
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    ba = batch_axes(mesh)
    ba_size = 1
    for a in ba:
        ba_size *= axis_size[a]

    def div(n: int, axes) -> bool:
        if axes is None:
            return False
        sz = 1
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            sz *= axis_size[a]
        return n % sz == 0

    b = ba if (shard_batch and batch % ba_size == 0) else None
    model_n = axis_size["model"]
    data_n = axis_size["data"]

    def kv_spec(n_kv: int, hd: int, C: int) -> P:
        s = "data" if (shard_seq and C % data_n == 0) else None
        if n_kv % model_n == 0:
            return P(None, b, s, "model", None)
        # GQA kv < model axis: shard the *sequence* dim over "model"
        # (flash-decode/context-parallel style — the partial softmax merge
        # lowers to small collectives, unlike gathering a hd-sharded cache)
        if s is None and C % model_n == 0:
            return P(None, b, "model", None, None)
        if hd % model_n == 0:
            return P(None, b, s, None, "model")
        return P(None, b, s, None, None)

    kinds = model_lib.kind_counts(cfg)
    hd = cfg.resolved_head_dim
    specs: dict = {}
    if "A" in kinds:
        C = min(capacity, cfg.sliding_window) if cfg.sliding_window \
            else capacity
        kv = kv_spec(cfg.num_kv_heads, hd, C)
        specs["A"] = {"k": kv, "v": kv}
    if "M" in kinds:
        nh = cfg.ssm_heads
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        specs["M"] = {
            "h": P(None, b, "model" if nh % model_n == 0 else None,
                   None, None),
            "conv": P(None, b, None,
                      "model" if conv_dim % model_n == 0 else None)}
    if "X" in kinds:
        di = int(cfg.xlstm_proj_factor * cfg.d_model)
        nh = cfg.num_heads
        xhd = di // nh
        h_ax = "model" if nh % model_n == 0 else None
        d_ax = "model" if (h_ax is None and xhd % model_n == 0) else None
        specs["X"] = {"C": P(None, b, h_ax, d_ax, None),
                      "n": P(None, b, h_ax, d_ax),
                      "m": P(None, b, h_ax)}
    if "S" in kinds:
        di = int(cfg.xlstm_proj_factor * cfg.d_model)
        sl = P(None, b, "model" if di % model_n == 0 else None)
        specs["S"] = {"c": sl, "n": sl, "h": sl, "m": sl}
    if model_lib.num_shared_invocations(cfg):
        kvh = cfg.shared_attn_kv_heads or cfg.num_kv_heads
        kv = kv_spec(kvh, hd, capacity)
        specs["shared"] = {"k": kv, "v": kv}
    return specs
