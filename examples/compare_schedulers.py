"""Trace-driven comparison of EconoServe against every baseline the paper
evaluates (fig 1 / fig 9 style), on a calibrated ShareGPT-like trace.

  PYTHONPATH=src python examples/compare_schedulers.py [--rate 5.0] [-n 300]
"""
import argparse

from repro.core import registry, traces


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=5.0)
    ap.add_argument("-n", type=int, default=300)
    ap.add_argument("--trace", default="sharegpt",
                    choices=list(traces.TRACES))
    args = ap.parse_args()

    reqs = traces.generate(traces.TRACES[args.trace], args.n, seed=1,
                           rate=args.rate)
    t_end = max(r.arrival for r in reqs)
    names = ["orca", "vllm", "sarathi", "multires", "distserve",
             "econoserve", "oracle"]
    print(f"{args.trace} trace, {args.n} requests at {args.rate}/s\n")
    print(f"{'scheduler':14s} {'steady tput':>11s} {'mean JCT':>9s} "
          f"{'norm lat':>9s} {'SSR':>6s} {'KVC util':>9s} {'fwd':>7s}")
    for name in names:
        res = registry.run_one(name, reqs)
        done = [r for r in res.completed if r.t_complete <= t_end]
        tput = len(done) / t_end
        s = res.summary()
        print(f"{name:14s} {tput:11.2f} {s['mean_jct_s']:9.2f} "
              f"{s['norm_latency_s_per_tok']:9.3f} {s['ssr']:6.3f} "
              f"{s['kvc_util']:9.3f} {s['fwd_size']:7.1f}")


if __name__ == "__main__":
    main()
