"""Train a reduced model of any assigned architecture for a few hundred
steps on synthetic data — exercises the full training substrate (AdamW,
data pipeline, remat'd layer scans, checkpointing).

  PYTHONPATH=src python examples/train_tiny.py --arch zamba2-7b --steps 60
"""
import argparse

from repro.configs import get_config
from repro.training import checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--save", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced().with_(
        dtype="float32", param_dtype="float32", vocab_size=512)
    print(f"training reduced {cfg.name}: {cfg.num_layers}L "
          f"d={cfg.d_model} pattern={cfg.pattern()}")
    params, _, hist = train(
        cfg, steps=args.steps,
        opt=AdamWConfig(lr=3e-3, warmup_steps=10),
        batch_size=8, seq_len=64, log_every=10,
        callback=lambda i, m: print(
            f"  step {i:4d} loss={m['loss']:.4f} gnorm={m['grad_norm']:.2f}"))
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    if args.save:
        checkpoint.save(args.save, params)
        print("saved", args.save)


if __name__ == "__main__":
    main()
