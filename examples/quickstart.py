"""Quickstart: serve a small model with batched requests under the
EconoServe scheduler (the paper's system, end to end, on CPU).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import get_config
from repro.serving import GenRequest, SamplingParams, ServingEngine


def main():
    # a reduced (2-layer) qwen3-family model — same code path as the
    # full config, which is exercised by the multi-pod dry-run
    cfg = get_config("qwen3-8b").reduced().with_(dtype="float32",
                                                 param_dtype="float32")
    engine = ServingEngine(cfg, max_batch=4, capacity=128)

    rng = np.random.default_rng(0)
    requests = [
        GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, n)),
                   params=SamplingParams(max_new_tokens=m))
        for n, m in [(12, 8), (20, 6), (7, 10), (15, 4), (9, 12), (18, 7)]
    ]
    engine.run(requests)

    for g in requests:
        print(f"request {g.rid}: prompt {len(g.prompt):3d} tokens -> "
              f"{len(g.output):2d} generated {g.output[:8]}...")
    s = engine.scheduler
    print(f"\nscheduler: {s.name} | completed={len(s.completed)} "
          f"| KVC alloc failures={s.kvc.n_failures} "
          f"| hosted (KVCPipe)={s.n_hosted}")


if __name__ == "__main__":
    main()
