"""End-to-end driver: serve an online stream of requests on real JAX
models with the EconoServe scheduler — on one engine or an N-instance
cluster fleet (``--cluster N``), optionally disaggregated into prefill /
decode roles with live KV migration (``--disagg``).

Requests arrive online (Poisson gaps on the iteration clock) through a
submit/step loop gated on ``has_work()``, and the report includes
per-request TTFT alongside throughput.

``--chaos SPEC`` injects scripted faults into a cluster run — e.g.
``kill@25:1`` (kill instance 1 at t=25), ``freeze@40:2/20`` (freeze
instance 2 for 20 iterations), ``slow@10:0/30x3``, ``corrupt@15``
(corrupt the next KV migration; caught by the inject-side checksum),
``squeeze@20:0/0.5`` (permanently drop half of instance 0's KVC
capacity at t=20 — the ``/`` clause is the fraction removed, not a
duration; pair with ``--kvc-tokens`` so the cache is tight enough for
the cut to bite). A fault-free reference run is served first and the
chaotic run must reproduce its greedy token streams bit-for-bit while
every request reaches exactly one terminal state (the conservation +
invariant audit from ``repro.cluster.faults``). A squeeze may push a
queued request past even the *empty* post-cut cache; rung 4 of the
pressure ladder sheds it terminally (``kvc-infeasible``) instead of
livelocking, and the equality gate covers every non-shed stream.

``--detect`` switches the fleet from *declared* to *detected* failure:
every routed message rides a seeded lossy transport, instances
heartbeat through it, and a lease-based failure detector owns observed
health (missed beats -> suspect, lease expiry -> dead, fresh beat ->
reinstated without losing work). It also arms the fleet shed-retry
tier: a rung-4 ``kvc-infeasible`` shed is re-routed to a peer whose
KVC can still fund it, and only shed terminally when no live peer can
ever fit. Three chaos kinds act on the transport (and require
``--detect``): ``drop@6:1/0.6`` (drop each message on instance 1's
link with p=0.6 for the window), ``dup@14:2/0.6`` (duplicate-deliver;
the receiver's idempotency table suppresses the copy), and
``delay@10:0/2.5`` (add 2.5 iterations of latency — reordering falls
out). With ``--detect`` and no chaos the run is bitwise-identical to
the direct path: the transport draws zero rng samples.

  PYTHONPATH=src python examples/serve_trace.py [--impl pallas] [-n 16]
  PYTHONPATH=src python examples/serve_trace.py --cluster 2 --router least-kvc
  PYTHONPATH=src python examples/serve_trace.py --cluster 2 --disagg --tiny
  PYTHONPATH=src python examples/serve_trace.py --cluster 3 --tiny \\
      --chaos kill@25:1
  PYTHONPATH=src python examples/serve_trace.py --cluster 2 --tiny \\
      --kvc-tokens 256 --chaos squeeze@20:0/0.5,squeeze@20:1/0.5
``--metrics PATH`` attaches a per-iteration ``MetricsSampler`` to every
engine (zero added blocking host syncs: device values come only from the
existing lag-N drain ring, host values at the step boundary the engine
already takes — ``hotpath_micro --check`` gates that metrics-on token
streams are bitwise-identical to metrics-off) and writes ``PATH.json``
(JSON snapshot) plus ``PATH.prom`` (Prometheus text, parsed back as a
self-check) at exit. Composes with ``--chaos``/``--detect``: the
fault-free reference runs metrics-off, so the token-equality gate also
proves the samplers changed nothing. The per-request report (TTFT,
KVC accounting) is itself read back from a registry snapshot — the
same families the dumps contain.

  PYTHONPATH=src python examples/serve_trace.py --cluster 3 --tiny \\
      --detect --chaos "drop@6:1/0.6,dup@14:2/0.6,kill@25:0"
  PYTHONPATH=src python examples/serve_trace.py --cluster 2 --tiny \\
      --chaos kill@25:1 --metrics /tmp/serve_metrics
"""
import argparse
import time

import numpy as np

from repro.cluster import (DetectorConfig, EngineFleet, HedgeConfig,
                           RecoveryConfig, ROUTERS, FaultInjector,
                           check_fleet_invariants, parse_chaos_spec)
from repro.configs import get_config
from repro.core.scheduler import SchedulerConfig
from repro.obs import (MetricsRegistry, MetricsSampler,
                       parse_prometheus_text, write_json_snapshot,
                       write_prometheus)
from repro.serving import GenRequest, SamplingParams, ServingEngine


def hist_quantile(h, q):
    """Bucket-resolution quantile from a HistogramValue snapshot (the
    first edge whose cumulative count covers the target rank)."""
    target = q * h.count
    for le, cum in h.buckets:
        if cum >= target:
            return le
    return float("inf")


def make_requests(cfg, n, rate, seed):
    rng = np.random.default_rng(seed)
    reqs = [GenRequest(
        prompt=list(rng.integers(0, cfg.vocab_size, rng.integers(6, 40))),
        params=SamplingParams(max_new_tokens=int(rng.integers(4, 16)),
                              temperature=0.0))
        for _ in range(n)]
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return reqs, arrivals


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b")
    ap.add_argument("-n", type=int, default=16)
    ap.add_argument("--impl", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--variant", default="full",
                    help="econoserve variant: d|sd|sdo|full")
    ap.add_argument("--cluster", type=int, default=0, metavar="N",
                    help="serve across N engine instances (0 = single)")
    ap.add_argument("--router", default="least-kvc", choices=list(ROUTERS))
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated roles: engine 0 prefills, the rest "
                         "decode (KV migration); requires --cluster >= 2")
    ap.add_argument("--chaos", default="", metavar="SPEC",
                    help="scripted fault schedule for a cluster run, e.g. "
                         "'kill@25:1,freeze@40:2/20,corrupt@15,"
                         "squeeze@20:0/0.5' (for squeeze the '/' clause is "
                         "the capacity fraction removed, default 0.5 — "
                         "permanent, not a duration) — the run must "
                         "recover: exactly-once terminal states, no leaks, "
                         "and every non-shed token stream equal to a "
                         "fault-free reference; requires --cluster >= 2. "
                         "Transport kinds drop@t:inst/p, dup@t:inst/p, "
                         "delay@t:inst/latency, and part@t:a|b/dur "
                         "(asymmetric network partition: instance a is "
                         "cut off from the side holding b and from the "
                         "control plane for dur iterations — a keeps "
                         "running as a zombie; its late completions are "
                         "fenced, never double-delivered) need --detect")
    ap.add_argument("--detect", action="store_true",
                    help="detected (not declared) failure: heartbeat/lease "
                         "detection over a lossy transport + the fleet "
                         "shed-retry tier; requires --cluster >= 2")
    ap.add_argument("--hedge", action="store_true",
                    help="straggler-aware hedged execution: a per-request "
                         "progress watchdog races a stalled (or suspect-"
                         "hosted) request on the best live peer; first "
                         "terminal transition wins, the loser is fenced + "
                         "cancelled; requires --detect")
    ap.add_argument("--hedge-factor", type=float, default=3.0,
                    help="stall threshold as a multiple of the rolling "
                         "p90 of observed TTFT / inter-token gaps")
    ap.add_argument("--hedge-floor", type=float, default=4.0,
                    help="minimum stall threshold in iterations (guards "
                         "against a cold/noisy estimator hair-triggering)")
    ap.add_argument("--kvc-tokens", type=int, default=0,
                    help="override the per-instance KVC budget in tokens "
                         "(0 = the derived max_batch*capacity default); "
                         "small values saturate the cache so pressure-"
                         "ladder smokes (e.g. --chaos squeeze@...) "
                         "actually bite")
    ap.add_argument("--metrics", default="", metavar="PATH",
                    help="attach per-iteration metrics samplers (zero "
                         "added blocking syncs) and write PATH.json + "
                         "PATH.prom registry dumps at exit")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="mean arrivals per engine iteration")
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized model (fast compile, smoke runs)")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    if args.disagg and args.cluster < 2:
        ap.error("--disagg needs --cluster >= 2")
    if args.chaos and args.cluster < 2:
        ap.error("--chaos needs --cluster >= 2 (a fleet to degrade)")
    if args.detect and args.cluster < 2:
        ap.error("--detect needs --cluster >= 2 (a fleet to observe)")
    if args.hedge and not args.detect:
        ap.error("--hedge needs --detect (the watchdog and the suspect "
                 "signal live on the detected-failure substrate)")
    cfg = get_config(args.arch).reduced().with_(dtype="float32",
                                                param_dtype="float32")
    if args.tiny:
        cfg = cfg.with_(d_model=64, num_heads=2, num_kv_heads=2,
                        head_dim=32, d_ff=256, vocab_size=256)
    kw = dict(max_batch=6, capacity=160, variant=args.variant,
              impl=args.impl)
    if args.kvc_tokens:
        kw["scheduler_cfg"] = SchedulerConfig(
            kvc_tokens=args.kvc_tokens, block_size=32, tfs=160,
            max_model_len=160, max_batch_reqs=6)
    n_inst = max(0, args.cluster)
    fkw = {}
    if args.chaos:
        fkw = dict(faults=FaultInjector(
                       schedule=parse_chaos_spec(args.chaos, n_inst)),
                   recovery=RecoveryConfig(max_retries=4, backoff_base=1.0,
                                           shed_retry=args.detect))
    if args.detect:
        fkw["detector"] = DetectorConfig()
        fkw.setdefault("recovery",
                       RecoveryConfig(max_retries=4, backoff_base=1.0,
                                      shed_retry=True))
    if args.hedge:
        fkw["hedge"] = HedgeConfig(ttft_factor=args.hedge_factor,
                                   rate_factor=args.hedge_factor,
                                   floor=args.hedge_floor)
    if n_inst:
        roles = ["prefill"] + ["decode"] * (n_inst - 1) if args.disagg \
            else None
        server = EngineFleet(cfg, n_instances=n_inst, roles=roles,
                             router=args.router, seed=args.seed, **fkw, **kw)
    else:
        server = ServingEngine(cfg, seed=args.seed, **kw)

    reg = MetricsRegistry()
    if args.metrics:
        if isinstance(server, EngineFleet):
            server.attach_metrics(reg)
        else:
            MetricsSampler(reg, instance="0").attach(server)

    ref_out = None
    if args.chaos:
        # fault-free reference on the same parameters: the chaotic run's
        # recovered token streams must match it bit-for-bit
        ref_reqs, ref_arr = make_requests(cfg, args.n, args.rate, args.seed)
        ref = ServingEngine(cfg, params=server.params, seed=args.seed, **kw)
        ref.run(ref_reqs, ref_arr)
        ref_out = [g.output for g in ref_reqs]

    reqs, arrivals = make_requests(cfg, args.n, args.rate, args.seed)

    # online submit/step loop on the iteration clock (both backends share
    # the run(reqs, arrivals) contract): requests are delivered at their
    # arrival time and the loop drains on has_work()
    t0 = time.time()
    server.run(reqs, arrivals)
    dt = time.time() - t0

    toks = sum(len(g.output) for g in reqs)
    done = sum(g.t_done is not None for g in reqs)
    if isinstance(server, EngineFleet):
        completed = server.completed_requests()
        cons = server.conservation()
        extra = (f"cluster={n_inst} router={args.router} "
                 f"migrations={cons['migrations']} "
                 f"conservation_ok={cons['ok']}")
        iids = [str(i.id) for i in server.instances]
    else:
        completed = server.scheduler.completed
        cons = None
        extra = "single-engine"
        iids = ["0"]

    # the per-request report is read back from a registry snapshot — the
    # same publication path debug_state and the --metrics dumps use, so
    # what's printed can never drift from what's exported
    server.publish_metrics(reg)
    ttft_h = reg.histogram(
        "report_ttft_iterations", "per-request time to first token on "
        "the iteration clock", buckets=(1, 2, 5, 10, 25, 50, 100, 250))
    for r in completed:
        if r.t_first_token is not None:
            ttft_h.unlabeled.observe(r.t_first_token - r.arrival)
    reg.gauge("report_served_requests",
              "requests that reached DONE").unlabeled.set(done)
    reg.gauge("report_generated_tokens",
              "tokens generated across all requests").unlabeled.set(toks)
    reg.gauge("report_wall_seconds", "serve wall time").unlabeled.set(dt)
    snap = reg.snapshot()

    ttft = snap.get("report_ttft_iterations")
    print(f"arch={cfg.name} impl={args.impl} variant={args.variant} {extra}")
    print(f"served {done}/{args.n} requests / {toks} tokens in {dt:.1f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s on CPU)")
    if ttft.count:
        print(f"TTFT (iterations): mean={ttft.sum / ttft.count:.1f} "
              f"p50<={hist_quantile(ttft, 0.5):.0f} "
              f"p95<={hist_quantile(ttft, 0.95):.0f}")
    fails = sum(snap.get("kvc_alloc_failures_total", instance=i) or 0
                for i in iids)
    fracs = [round(snap.get("kvc_allocated_frac", instance=i) or 0.0, 2)
             for i in iids]
    print(f"KVC accounting: failures={fails:.0f}, alloc_frac={fracs}")

    if args.metrics:
        write_json_snapshot(snap, args.metrics + ".json",
                            extra={"argv": vars(args)})
        write_prometheus(snap, args.metrics + ".prom")
        with open(args.metrics + ".prom") as fh:
            parse_prometheus_text(fh.read())     # export self-check
        n_sampled = sum(snap.get("sampler_samples_total", instance=i) or 0
                        for i in iids)
        print(f"metrics: wrote {args.metrics}.json / .prom "
              f"({n_sampled:.0f} sampler ticks)")

    if args.hedge:
        hc = server.hedge.counters()
        print(f"hedge: fired={hc['hedges_fired']} won={hc['hedges_won']} "
              f"cancelled={hc['hedges_cancelled']} "
              f"fenced={server.n_fenced_completions} "
              f"stale_drops={server.n_stale_drops}")

    if args.chaos:
        report = check_fleet_invariants(server)
        # a squeeze may shed permanently-infeasible requests (rung 4);
        # every surviving stream must still match the fault-free run
        equal = all(g.output == r for g, r in zip(reqs, ref_out)
                    if g.status != "shed")
        print(f"chaos: faults={server.faults.log} "
              f"recovered={server.n_recovered} "
              f"aborted={cons['aborted']} shed={cons['shed']} "
              f"kv_rejects={cons['kv_rejects']} "
              f"invariants_ok={report['ok']} tokens_equal={equal}")
        if args.detect:
            tr = server.transport
            print(f"detect: transitions={server.detector.transitions} "
                  f"reinstated={server.detector.n_reinstated} "
                  f"dropped={tr.n_dropped} duplicated={tr.n_duplicated} "
                  f"retransmits={tr.n_retransmits} "
                  f"dup_suppressed={cons['dup_deliveries']} "
                  f"shed_rescued={cons['shed_rescued']}")
        if not (cons["ok"] and report["ok"] and equal):
            raise SystemExit(1)
        if args.hedge and server.hedge.counters()["hedges_won"] < 1:
            # the schedule was chosen to make hedging matter: a run where
            # no clone ever beat its primary means the tier never engaged
            raise SystemExit(1)
        terminal = done + cons["aborted"] + cons["shed"]
        if terminal != args.n:
            raise SystemExit(1)
    elif done != args.n:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
