"""End-to-end driver (deliverable b): serve a stream of batched requests on
a real JAX model with the EconoServe scheduler, Poisson arrivals, EOS
stopping and the Pallas attention path.

  PYTHONPATH=src python examples/serve_trace.py [--impl pallas] [-n 16]
"""
import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.serving import GenRequest, SamplingParams, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b")
    ap.add_argument("-n", type=int, default=16)
    ap.add_argument("--impl", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--variant", default="full",
                    help="econoserve variant: d|sd|sdo|full")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced().with_(dtype="float32",
                                                param_dtype="float32")
    engine = ServingEngine(cfg, max_batch=6, capacity=160,
                           variant=args.variant, impl=args.impl)
    rng = np.random.default_rng(7)
    reqs = [GenRequest(
        prompt=list(rng.integers(0, cfg.vocab_size, rng.integers(6, 40))),
        params=SamplingParams(max_new_tokens=int(rng.integers(4, 16)),
                              temperature=0.0))
        for _ in range(args.n)]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    toks = sum(len(g.output) for g in reqs)
    print(f"arch={cfg.name} impl={args.impl} variant={args.variant}")
    print(f"served {args.n} requests / {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s on CPU)")
    s = engine.scheduler
    print(f"KVC utilization accounting: failures={s.kvc.n_failures}, "
          f"hosted={s.n_hosted}, reserve rescues={s.n_reserve_rescues}")


if __name__ == "__main__":
    main()
