"""End-to-end driver: serve an online stream of requests on real JAX
models with the EconoServe scheduler — on one engine or an N-instance
cluster fleet (``--cluster N``), optionally disaggregated into prefill /
decode roles with live KV migration (``--disagg``).

Requests arrive online (Poisson gaps on the iteration clock) through a
submit/step loop gated on ``has_work()``, and the report includes
per-request TTFT alongside throughput.

  PYTHONPATH=src python examples/serve_trace.py [--impl pallas] [-n 16]
  PYTHONPATH=src python examples/serve_trace.py --cluster 2 --router least-kvc
  PYTHONPATH=src python examples/serve_trace.py --cluster 2 --disagg --tiny
"""
import argparse
import time

import numpy as np

from repro.cluster import EngineFleet, ROUTERS
from repro.configs import get_config
from repro.serving import GenRequest, SamplingParams, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b")
    ap.add_argument("-n", type=int, default=16)
    ap.add_argument("--impl", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--variant", default="full",
                    help="econoserve variant: d|sd|sdo|full")
    ap.add_argument("--cluster", type=int, default=0, metavar="N",
                    help="serve across N engine instances (0 = single)")
    ap.add_argument("--router", default="least-kvc", choices=list(ROUTERS))
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated roles: engine 0 prefills, the rest "
                         "decode (KV migration); requires --cluster >= 2")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="mean arrivals per engine iteration")
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized model (fast compile, smoke runs)")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    if args.disagg and args.cluster < 2:
        ap.error("--disagg needs --cluster >= 2")
    cfg = get_config(args.arch).reduced().with_(dtype="float32",
                                                param_dtype="float32")
    if args.tiny:
        cfg = cfg.with_(d_model=64, num_heads=2, num_kv_heads=2,
                        head_dim=32, d_ff=256, vocab_size=256)
    kw = dict(max_batch=6, capacity=160, variant=args.variant,
              impl=args.impl)
    n_inst = max(0, args.cluster)
    if n_inst:
        roles = ["prefill"] + ["decode"] * (n_inst - 1) if args.disagg \
            else None
        server = EngineFleet(cfg, n_instances=n_inst, roles=roles,
                             router=args.router, seed=args.seed, **kw)
    else:
        server = ServingEngine(cfg, seed=args.seed, **kw)

    rng = np.random.default_rng(args.seed)
    reqs = [GenRequest(
        prompt=list(rng.integers(0, cfg.vocab_size, rng.integers(6, 40))),
        params=SamplingParams(max_new_tokens=int(rng.integers(4, 16)),
                              temperature=0.0))
        for _ in range(args.n)]
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, size=args.n))

    # online submit/step loop on the iteration clock (both backends share
    # the run(reqs, arrivals) contract): requests are delivered at their
    # arrival time and the loop drains on has_work()
    t0 = time.time()
    server.run(reqs, arrivals)
    dt = time.time() - t0

    toks = sum(len(g.output) for g in reqs)
    done = sum(g.t_done is not None for g in reqs)
    if isinstance(server, EngineFleet):
        completed = server.completed_requests()
        cons = server.conservation()
        extra = (f"cluster={n_inst} router={args.router} "
                 f"migrations={cons['migrations']} "
                 f"conservation_ok={cons['ok']}")
        kvcs = [i.engine.scheduler.kvc for i in server.instances]
    else:
        completed = server.scheduler.completed
        extra = "single-engine"
        kvcs = [server.scheduler.kvc]
    ttfts = sorted(r.t_first_token - r.arrival for r in completed
                   if r.t_first_token is not None)
    print(f"arch={cfg.name} impl={args.impl} variant={args.variant} {extra}")
    print(f"served {done}/{args.n} requests / {toks} tokens in {dt:.1f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s on CPU)")
    if ttfts:
        p95 = ttfts[min(len(ttfts) - 1, int(0.95 * len(ttfts)))]
        print(f"TTFT (iterations): mean={np.mean(ttfts):.1f} "
              f"p50={ttfts[len(ttfts) // 2]:.1f} p95={p95:.1f}")
    fails = sum(k.n_failures for k in kvcs)
    print(f"KVC accounting: failures={fails}, "
          f"alloc_frac={[round(k.allocated_frac, 2) for k in kvcs]}")
    if done != args.n:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
